//! Sharded coordinator: N [`Scheduler`] shards plus work-stealing.
//!
//! Partitions the context registry across `N` shard instances of the
//! existing scheduler — each shard owns a disjoint subset of contexts
//! (their queues, warm sets and incremental indexes from the O(changes)
//! dispatch work) and runs its own dispatch rounds against its own
//! [`PlacementPolicy`](super::policy::PlacementPolicy). Workers have a
//! **home shard** keyed by node id (`node % shards`), and a
//! work-stealing layer lends idle workers from drained shards to
//! backlogged peers:
//!
//! * **Lend** — after the per-shard dispatch rounds, any shard with a
//!   backlog and no idle workers borrows the lowest-id idle worker of a
//!   shard with an empty queue, via [`Scheduler::worker_lend`] /
//!   [`Scheduler::worker_adopt`] (cache and library state travel with
//!   the worker). A worker is owned by exactly one shard at any time —
//!   the lend removes it from every lender index before the adopt
//!   inserts it anywhere.
//! * **Return** — a lent worker goes home as soon as it is idle and
//!   either its borrower has drained or its home shard has backlog
//!   again, so steady state converges on the home partition.
//!
//! Identifier spaces stay global: the coordinator owns worker-id
//! allocation (shards are told the next id before every routed join)
//! and gives each shard a disjoint prefetch-sequence base, so every
//! dispatch id in a trace is unique and prefetch ids encode their
//! owning shard. Trace events flow through one shared sink; each
//! shard's scheduler stamps its events with its shard id (multi-shard
//! runs only — a single-shard coordinator emits byte-identical traces
//! to an unsharded [`Scheduler`], which is the equivalence `pcm
//! experiment shards` proves at trace level).
//!
//! Both drivers ([`super::sim_driver`], [`crate::live`]) drive this
//! coordinator exclusively; `shards = 1` is the degenerate — and
//! default — configuration.
//!
//! # Threading model
//!
//! The coordinator itself is single-threaded (`&mut self` everywhere),
//! but it is built to be *dismembered* for the threaded live runtime
//! ([`crate::live::threaded`]): [`ShardedCoordinator::into_parts`]
//! moves each [`Scheduler`] shard out so a dedicated thread can own it,
//! and [`ShardedCoordinator::reassemble`] puts the pieces back together
//! after the threads are joined (for records, cache stats and the
//! conservation checks). The ownership rules that make that sound:
//!
//! * A `Scheduler` is `Send` (moved into a shard thread) but not
//!   shared — each thread owns exactly one shard, and every mutation
//!   of a shard happens on its thread.
//! * A [`Worker`] moved between shards (lend / return / adopt) must
//!   never be visible to two shard threads at once. The serial
//!   steal/return passes guarantee this trivially; the threaded
//!   runtime re-creates the guarantee with a two-phase message handoff
//!   (the worker travels *inside* a channel message, owned by neither
//!   thread while in transit).
//! * Routing maps (`ctx_shard`, `task_shard`, `worker_shard`,
//!   `home_shard`), the worker-id allocator and the steal counter stay
//!   with whichever thread plays coordinator; shards never read them.
//! * The [`TraceHandle`] is the one deliberately shared surface: it is
//!   `Send + Sync` (sink behind a mutex) and every shard clones it, so
//!   per-shard `dispatch_round` events interleave safely.

use std::collections::HashMap;

use crate::cluster::{Node, NodeId};
use crate::obs::{TraceEvent, TraceHandle};

use super::context::{ContextId, ContextPolicy, ContextRecipe};
use super::costmodel::CostModel;
use super::metrics::CacheStats;
use super::policy::PolicyKind;
use super::scheduler::{Dispatch, PhaseKind, Progress, Scheduler};
use super::task::{Task, TaskId, TaskRecord};
use super::transfer::TransferPlanner;
use super::worker::{Worker, WorkerId};

/// Bit offset of the shard index inside a synthetic prefetch id: shard
/// `k` draws ids from `PREFETCH_ID_BASE + (k << 40)`, leaving 2^40
/// sequence numbers per shard (no run issues remotely that many) while
/// keeping the id below the `1 << 62` base's headroom for any
/// realistic shard count.
pub(crate) const PREFETCH_SHARD_SHIFT: u64 = 40;

/// N scheduler shards behind the single-coordinator API both drivers
/// program against. See the module docs for the ownership rules.
#[derive(Debug)]
pub struct ShardedCoordinator {
    shards: Vec<Scheduler>,
    /// Context → owning shard (fixed at construction).
    ctx_shard: HashMap<ContextId, usize>,
    /// Task → owning shard (the submit route, kept for O(1) completion
    /// routing; prefetch ids route arithmetically instead).
    task_shard: HashMap<TaskId, usize>,
    /// Worker → shard currently holding it (moves on lend/return).
    worker_shard: HashMap<WorkerId, usize>,
    /// Worker → home shard (`node % shards`, fixed per incarnation).
    home_shard: HashMap<WorkerId, usize>,
    /// Globally monotone worker-id allocator (shards are told).
    next_worker_id: WorkerId,
    /// Workers lent to a backlogged peer shard over the run.
    steals: u64,
    /// Whether `dispatch_all` runs the steal/return passes. The
    /// threaded live runtime disables them here (the coordinator
    /// thread initiates lends itself); parity experiments disable
    /// them to keep N-shard and 1-shard schedules comparable.
    steal_enabled: bool,
    trace: TraceHandle,
}

/// The dismembered coordinator: every shard's [`Scheduler`] plus the
/// routing/allocator state, moved out by
/// [`ShardedCoordinator::into_parts`] so shard threads can each own a
/// scheduler. Reassembled after thread join via
/// [`ShardedCoordinator::reassemble`]. Field meanings match the
/// coordinator's own fields one-for-one.
#[derive(Debug)]
pub struct ShardParts {
    pub shards: Vec<Scheduler>,
    pub ctx_shard: HashMap<ContextId, usize>,
    pub task_shard: HashMap<TaskId, usize>,
    pub worker_shard: HashMap<WorkerId, usize>,
    pub home_shard: HashMap<WorkerId, usize>,
    pub next_worker_id: WorkerId,
    pub steals: u64,
    pub trace: TraceHandle,
}

impl ShardedCoordinator {
    /// Build `shards` scheduler shards over one shared context registry.
    /// The shard count is clamped to the registry size (a shard without
    /// a context would never receive work) and to a minimum of 1.
    /// Contexts are assigned round-robin in ascending id order, so two
    /// coordinators built from the same registry agree on the partition.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        shards: usize,
        policy: ContextPolicy,
        mut recipes: Vec<ContextRecipe>,
        fanout_cap: u32,
        cost: CostModel,
        cache_capacity_bytes: u64,
        placement: PolicyKind,
        trace: TraceHandle,
    ) -> Self {
        assert!(!recipes.is_empty(), "context registry must not be empty");
        recipes.sort_by_key(|r| r.id);
        let n = shards.max(1).min(recipes.len());
        let ctx_shard: HashMap<ContextId, usize> = recipes
            .iter()
            .enumerate()
            .map(|(i, r)| (r.id, i % n))
            .collect();
        let shards = (0..n)
            .map(|k| {
                // Every shard registers the full registry (recipes are
                // metadata; a lent worker may carry any context's bytes
                // into any shard) — only tasks are partitioned.
                let mut s = Scheduler::with_registry(
                    policy,
                    recipes.clone(),
                    TransferPlanner::new(fanout_cap),
                    cost.clone(),
                    cache_capacity_bytes,
                )
                .with_policy(placement.build())
                .with_trace(trace.clone());
                if n > 1 {
                    s = s.with_shard_id(k as u32);
                }
                s.set_prefetch_seq_base((k as u64) << PREFETCH_SHARD_SHIFT);
                s
            })
            .collect();
        Self {
            shards,
            ctx_shard,
            task_shard: HashMap::new(),
            worker_shard: HashMap::new(),
            home_shard: HashMap::new(),
            next_worker_id: 0,
            steals: 0,
            steal_enabled: true,
            trace,
        }
    }

    /// Enable or disable the steal/return passes inside
    /// [`dispatch_all`](Self::dispatch_all). With stealing off a
    /// dispatch round touches only home-partition state, which is what
    /// the threaded runtime's per-shard loops need (cross-shard moves
    /// go through the coordinator thread's two-phase handoff instead)
    /// and what the trace-parity experiments need for N-vs-1
    /// comparability.
    // pcm-lint: allow(untraced|unindexed) -- configuration toggle; no
    // scheduler state transition to trace or index.
    pub fn set_stealing(&mut self, on: bool) {
        self.steal_enabled = on;
    }

    /// Move every shard (and the routing/allocator state) out of the
    /// coordinator so each [`Scheduler`] can be owned by its own
    /// thread. Takes `self` by value: once dismembered, the only way
    /// back to the coordinator API is [`Self::reassemble`] — there is
    /// no window where a coordinator and a thread both own a shard.
    pub fn into_parts(self) -> ShardParts {
        ShardParts {
            shards: self.shards,
            ctx_shard: self.ctx_shard,
            task_shard: self.task_shard,
            worker_shard: self.worker_shard,
            home_shard: self.home_shard,
            next_worker_id: self.next_worker_id,
            steals: self.steals,
            trace: self.trace,
        }
    }

    /// Rebuild a coordinator from parts previously moved out by
    /// [`Self::into_parts`] (after the shard threads are joined and
    /// their schedulers collected back into `parts.shards`). The
    /// reassembled coordinator serves `records()`, `cache_stats()`,
    /// `progress()` and the conservation/index checks exactly as if it
    /// had never been taken apart.
    pub fn reassemble(parts: ShardParts) -> Self {
        Self {
            shards: parts.shards,
            ctx_shard: parts.ctx_shard,
            task_shard: parts.task_shard,
            worker_shard: parts.worker_shard,
            home_shard: parts.home_shard,
            next_worker_id: parts.next_worker_id,
            steals: parts.steals,
            steal_enabled: true,
            trace: parts.trace,
        }
    }

    // ------------------------------------------------------------ routing

    /// Number of shard instances (after clamping).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning a context's queue.
    pub fn shard_of_ctx(&self, ctx: ContextId) -> usize {
        self.ctx_shard.get(&ctx).copied().unwrap_or(0)
    }

    /// Home shard of a node (and of every worker incarnation on it).
    /// Live drivers route worker completions to this shard's channel.
    pub fn home_shard_of_node(&self, node: NodeId) -> usize {
        node as usize % self.shards.len()
    }

    /// Shard encoded in a synthetic prefetch-dispatch id.
    fn shard_of_prefetch(&self, id: TaskId) -> usize {
        debug_assert!(Scheduler::is_prefetch_id(id));
        (((id - Scheduler::PREFETCH_ID_BASE) >> PREFETCH_SHARD_SHIFT)
            as usize)
            % self.shards.len()
    }

    /// Shard owning any dispatch id (task or prefetch), if known.
    fn shard_of_dispatch(&self, id: TaskId) -> Option<usize> {
        if Scheduler::is_prefetch_id(id) {
            Some(self.shard_of_prefetch(id))
        } else {
            self.task_shard.get(&id).copied()
        }
    }

    // ------------------------------------------------------ workload flow

    /// Route each task to its context's shard (relative order within a
    /// shard is submission order, so per-context FIFO is preserved).
    // pcm-lint: allow(untraced) -- each shard's submit_tasks emits
    // task_submit through the shared sink.
    pub fn submit_tasks(&mut self, tasks: Vec<Task>) {
        let mut per: Vec<Vec<Task>> = vec![Vec::new(); self.shards.len()];
        for t in tasks {
            let k = self.shard_of_ctx(t.context);
            self.task_shard.insert(t.id, k);
            per[k].push(t);
        }
        for (k, ts) in per.into_iter().enumerate() {
            if !ts.is_empty() {
                self.shards[k].submit_tasks(ts);
            }
        }
    }

    /// Register a worker on its node's home shard. The coordinator owns
    /// the global id space: the shard is told which id to use, so ids
    /// stay unique across shards (the trace replay ledger keys workers
    /// globally).
    // pcm-lint: allow(untraced) -- the home shard's worker_join emits
    // worker_join stamped with its shard id.
    pub fn worker_join(&mut self, node: Node, now: f64) -> WorkerId {
        let k = self.home_shard_of_node(node.id);
        self.shards[k].set_next_worker_id(self.next_worker_id);
        let wid = self.shards[k].worker_join(node, now);
        debug_assert_eq!(wid, self.next_worker_id);
        self.next_worker_id = wid + 1;
        self.worker_shard.insert(wid, k);
        self.home_shard.insert(wid, k);
        wid
    }

    /// Evict a worker wherever it currently is. If it died while lent
    /// away from home, its node's surviving disk snapshot migrates to
    /// the home shard's ledger — the node rejoins through its home
    /// shard, and one physical disk must have exactly one ledger entry.
    // pcm-lint: allow(untraced) -- the owning shard's worker_evict
    // emits worker_lost / cache_persist.
    pub fn worker_evict(&mut self, id: WorkerId) -> Option<(TaskId, u64)> {
        let cur = self.worker_shard.remove(&id)?;
        let home = self.home_shard.remove(&id).unwrap_or(cur);
        let node = self.shards[cur].worker(id).map(|w| w.node_id());
        let freed = self.shards[cur].worker_evict(id);
        if cur != home {
            if let Some(node) = node {
                if let Some(entry) = self.shards[cur].take_node_cache(node) {
                    self.shards[home].put_node_cache(node, entry);
                }
            }
        }
        freed
    }

    /// A phase finished: route to the owning shard (tasks by submit
    /// route, prefetches by the shard encoded in their id).
    // pcm-lint: allow(untraced|unindexed) -- pure route-and-delegate;
    // the owning shard's phase_done traces and indexes the transition.
    pub fn phase_done(
        &mut self,
        task: TaskId,
        phase: usize,
    ) -> Option<PhaseKind> {
        let k = self.shard_of_dispatch(task)?;
        self.shards[k].phase_done(task, phase)
    }

    /// Record a task completion on its owning shard.
    // pcm-lint: allow(untraced|unindexed) -- pure route-and-delegate;
    // the owning shard's task_done traces and indexes the completion.
    pub fn task_done(&mut self, task: TaskId, record: TaskRecord) {
        if let Some(k) = self.shard_of_dispatch(task) {
            self.shards[k].task_done(task, record);
        }
    }

    /// Drain every shard's pending LRU evictions (shard order).
    // pcm-lint: allow(untraced|unindexed) -- drains queues the shards'
    // cache choke points already traced and indexed.
    pub fn take_evictions(&mut self) -> Vec<(WorkerId, ContextId)> {
        self.shards.iter_mut().flat_map(|s| s.take_evictions()).collect()
    }

    // --------------------------------------------------- dispatch + steal

    /// One coordinator-wide dispatch round at `now`: every shard runs
    /// its own timed round (emitting its own `dispatch_round` event),
    /// then the work-stealing pass lends idle workers of drained shards
    /// to backlogged peers (re-dispatching each borrower), then lent
    /// workers whose borrower drained — or whose home backlogged — go
    /// home. Returns every dispatch decided, in decision order.
    // pcm-lint: allow(untraced|unindexed) -- shard_round emits each
    // shard's dispatch_round; the steal/return passes maintain the
    // worker_shard routing map.
    pub fn dispatch_all(&mut self, now: f64) -> Vec<Dispatch> {
        let mut out = Vec::new();
        for k in 0..self.shards.len() {
            self.shards[k].set_clock_hint(now);
            self.shard_round(k, now, &mut out);
        }
        if self.steal_enabled {
            self.steal_pass(now, &mut out);
            self.return_pass(now, &mut out);
        }
        out
    }

    /// One timed dispatch round on shard `k` (the per-shard analogue of
    /// the round the drivers used to time themselves).
    fn shard_round(&mut self, k: usize, now: f64, out: &mut Vec<Dispatch>) {
        let s = &mut self.shards[k];
        let t0 = s.trace().on().then(std::time::Instant::now);
        let dispatches = s.try_dispatch();
        if let Some(t0) = t0 {
            let assigned =
                dispatches.iter().filter(|d| !d.is_prefetch()).count() as u64;
            let prefetched = dispatches.len() as u64 - assigned;
            let ev = TraceEvent::DispatchRound {
                at: now,
                policy: s.placement_name().to_string(),
                assigned,
                prefetched,
                queued: s.ready_count() as u64,
                wall_s: t0.elapsed().as_secs_f64(),
                shard: s.shard_id(),
            };
            s.trace().emit(ev);
        }
        out.extend(dispatches);
    }

    /// Lend idle workers of drained shards to backlogged peers. Each
    /// iteration moves exactly one worker and re-dispatches the
    /// borrower, so the loop terminates: a lent worker either starts a
    /// task (leaves the idle pool) or parks idle in a shard that then
    /// no longer qualifies as a borrower — and a shard with backlog
    /// never qualifies as a lender.
    fn steal_pass(&mut self, now: f64, out: &mut Vec<Dispatch>) {
        let n = self.shards.len();
        loop {
            let Some(borrower) = (0..n).find(|&k| {
                self.shards[k].ready_count() > 0
                    && self.shards[k].idle_count() == 0
            }) else {
                break;
            };
            let Some(lender) = (0..n).find(|&k| {
                k != borrower
                    && self.shards[k].ready_count() == 0
                    && self.shards[k].idle_count() > 0
            }) else {
                break;
            };
            // Lowest idle id first: deterministic, and (ids being
            // join-ordered) biased toward the longest-lived caches.
            let Some(&wid) = self.shards[lender].idle_worker_ids().first()
            else {
                break;
            };
            let Some(w) = self.shards[lender].worker_lend(wid) else {
                break;
            };
            self.shards[borrower].worker_adopt(w);
            self.worker_shard.insert(wid, borrower);
            self.steals += 1;
            self.shard_round(borrower, now, out);
        }
    }

    /// Send lent workers home once they are idle and either their
    /// borrower has drained or their home shard has backlog again. A
    /// home shard that regains a worker with work waiting dispatches it
    /// immediately.
    fn return_pass(&mut self, now: f64, out: &mut Vec<Dispatch>) {
        let mut away: Vec<(WorkerId, usize, usize)> = self
            .worker_shard
            .iter()
            .filter_map(|(&w, &cur)| {
                let home = *self.home_shard.get(&w)?;
                (home != cur).then_some((w, cur, home))
            })
            .collect();
        away.sort_unstable();
        let mut redispatch = Vec::new();
        for (wid, cur, home) in away {
            if self.shards[cur].ready_count() > 0
                && self.shards[home].ready_count() == 0
            {
                continue; // still needed where it is
            }
            // `worker_lend` refuses busy workers, which is exactly the
            // "idle in the borrower" condition.
            if let Some(w) = self.shards[cur].worker_lend(wid) {
                self.shards[home].worker_adopt(w);
                self.worker_shard.insert(wid, home);
                if self.shards[home].ready_count() > 0 {
                    redispatch.push(home);
                }
            }
        }
        redispatch.dedup();
        for k in redispatch {
            self.shard_round(k, now, out);
        }
    }

    // ------------------------------------------------------- pass-through

    /// The shared trace handle (drivers emit run-level events — run
    /// start, node churn — through the same sink the shards stamp).
    pub fn trace(&self) -> &TraceHandle {
        &self.trace
    }

    pub fn worker(&self, id: WorkerId) -> Option<&Worker> {
        let k = self.worker_shard.get(&id)?;
        self.shards[*k].worker(id)
    }

    /// The live worker on `node`, wherever it is currently lent.
    pub fn worker_on_node(&self, node: NodeId) -> Option<WorkerId> {
        self.shards.iter().find_map(|s| s.worker_on_node(node))
    }

    /// Broadcast the driver clock to every shard (trace stamps and
    /// lifetime arithmetic).
    // pcm-lint: allow(untraced|unindexed) -- clock broadcast; no state
    // transition to trace or index.
    pub fn set_clock_hint(&mut self, now: f64) {
        for s in &mut self.shards {
            s.set_clock_hint(now);
        }
    }

    /// Broadcast a node's next-reclamation forecast (the worker may be
    /// lent to any shard when the forecast matters).
    // pcm-lint: allow(untraced|unindexed) -- forecast broadcast; each
    // shard indexes its own placement hint.
    pub fn set_node_reclaim_hint(&mut self, node: NodeId, at: Option<f64>) {
        for s in &mut self.shards {
            s.set_node_reclaim_hint(node, at);
        }
    }

    /// Drop a node's disk snapshot from whichever ledger holds it.
    // pcm-lint: allow(untraced|unindexed) -- ledger broadcast; the
    // holding shard's drop emits the trace event.
    pub fn drop_node_cache(&mut self, node: NodeId) {
        for s in &mut self.shards {
            s.drop_node_cache(node);
        }
    }

    /// Bump a context's registry version on every shard (the registry
    /// is replicated; versions must agree wherever a lent worker's
    /// cache is judged for staleness). Returns the owning shard's new
    /// version.
    // pcm-lint: allow(untraced|unindexed) -- registry broadcast; every
    // shard's bump emits version_bump and refreshes warmth.
    pub fn bump_context_version(&mut self, ctx: ContextId) -> Option<u32> {
        let owner = self.shard_of_ctx(ctx);
        let mut v = None;
        for (k, s) in self.shards.iter_mut().enumerate() {
            let bumped = s.bump_context_version(ctx);
            if k == owner {
                v = bumped;
            }
        }
        v
    }

    pub fn all_done(&self) -> bool {
        self.shards.iter().all(|s| s.all_done())
    }

    pub fn ready_count(&self) -> usize {
        self.shards.iter().map(|s| s.ready_count()).sum()
    }

    pub fn running_count(&self) -> usize {
        self.shards.iter().map(|s| s.running_count()).sum()
    }

    pub fn connected_workers(&self) -> usize {
        self.shards.iter().map(|s| s.connected_workers()).sum()
    }

    pub fn total_tasks(&self) -> usize {
        self.shards.iter().map(|s| s.total_tasks()).sum()
    }

    /// Progress counters summed across shards.
    pub fn progress(&self) -> Progress {
        let mut p = Progress::default();
        for s in self.shards.iter().map(|s| s.progress()) {
            p.completed_tasks += s.completed_tasks;
            p.completed_inferences += s.completed_inferences;
            p.evicted_inferences += s.evicted_inferences;
            p.evictions += s.evictions;
        }
        p
    }

    /// Completion records of every shard. Single-shard keeps the
    /// shard's completion order exactly (the unsharded contract);
    /// multi-shard merges by completion time (ties by task id) so the
    /// result is independent of shard count for identical schedules.
    pub fn records(&self) -> Vec<TaskRecord> {
        if self.shards.len() == 1 {
            return self.shards[0].records().to_vec();
        }
        let mut all: Vec<TaskRecord> = self
            .shards
            .iter()
            .flat_map(|s| s.records().iter().cloned())
            .collect();
        all.sort_by(|a, b| {
            a.completed_at
                .total_cmp(&b.completed_at)
                .then(a.task.cmp(&b.task))
        });
        all
    }

    /// Per-context cache counters merged across shards. Counters for
    /// one context can land on several shards (a lent worker's LRU
    /// evictions are charged where it was borrowed), so this sums
    /// field-wise by context.
    pub fn cache_stats(&self) -> CacheStats {
        let mut merged = CacheStats::default();
        for s in &self.shards {
            for (ctx, c) in &s.cache_stats().per_context {
                let m = merged.ctx_mut(*ctx);
                m.hits += c.hits;
                m.misses += c.misses;
                m.evictions += c.evictions;
                m.prefetched += c.prefetched;
                m.staged_bytes += c.staged_bytes;
                m.warm_restored += c.warm_restored;
                m.warm_restored_bytes += c.warm_restored_bytes;
                m.stale_dropped += c.stale_dropped;
            }
        }
        merged
    }

    pub fn task_meta(&self, id: TaskId) -> Option<(u32, u64)> {
        let k = self.task_shard.get(&id)?;
        self.shards[*k].task_meta(id)
    }

    pub fn task_context(&self, id: TaskId) -> Option<ContextId> {
        let k = self.task_shard.get(&id)?;
        self.shards[*k].task_context(id)
    }

    pub fn task_range(&self, id: TaskId) -> Option<(u64, u64)> {
        let k = self.task_shard.get(&id)?;
        self.shards[*k].task_range(id)
    }

    /// Context of any dispatch id (tasks and prefetch ids alike).
    pub fn dispatch_context(&self, id: TaskId) -> Option<ContextId> {
        let k = self.shard_of_dispatch(id)?;
        self.shards[k].dispatch_context(id)
    }

    /// The (replicated) registry — every shard holds the same recipes.
    pub fn recipes(&self) -> impl Iterator<Item = &ContextRecipe> {
        self.shards[0].recipes()
    }

    /// Name of the placement policy every shard runs.
    pub fn placement_name(&self) -> &'static str {
        self.shards[0].placement_name()
    }

    /// Workers lent to a backlogged peer shard over the run.
    pub fn steals(&self) -> u64 {
        self.steals
    }

    // --------------------------------------------------------- invariants

    /// Task conservation on every shard, plus routing coherence: the
    /// coordinator's task routes cover exactly the shards' tasks.
    pub fn check_conservation(&self) -> bool {
        self.shards.iter().all(|s| s.check_conservation())
            && self.task_shard.len() == self.total_tasks()
    }

    /// Index coherence on every shard, plus worker-routing coherence:
    /// every routed worker exists in exactly the shard the coordinator
    /// says, and no worker is owned by two shards.
    pub fn check_index_consistency(&self) -> bool {
        if !self.shards.iter().all(|s| s.check_index_consistency()) {
            return false;
        }
        if self.worker_shard.len() != self.connected_workers() {
            return false;
        }
        if self.home_shard.len() != self.worker_shard.len() {
            return false;
        }
        self.worker_shard.iter().all(|(wid, &k)| {
            self.shards[k].worker(*wid).is_some()
                && self
                    .shards
                    .iter()
                    .enumerate()
                    .all(|(j, s)| j == k || s.worker(*wid).is_none())
        })
    }
}

// The threaded live runtime moves a `Scheduler` into each shard thread
// and a `Worker` through channels between them; the shared
// `TraceHandle` is cloned into every thread. Assert the `Send` bounds
// at compile time so a policy or sink losing `Send` fails here, with a
// named function, instead of deep inside `live::threaded`.
const _: () = {
    const fn assert_send<T: Send>() {}
    const fn assert_send_sync<T: Send + Sync>() {}
    let _ = assert_send::<Scheduler>;
    let _ = assert_send::<Worker>;
    let _ = assert_send::<ShardParts>;
    let _ = assert_send_sync::<TraceHandle>;
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    fn two_ctx_recipes() -> Vec<ContextRecipe> {
        vec![
            ContextRecipe::custom(0, "a", 1_000_000, 2_000_000),
            ContextRecipe::custom(1, "b", 1_000_000, 2_000_000),
        ]
    }

    fn mk(shards: usize) -> ShardedCoordinator {
        let mut cost = CostModel::default();
        cost.deterministic = true;
        ShardedCoordinator::new(
            shards,
            ContextPolicy::Pervasive,
            two_ctx_recipes(),
            3,
            cost,
            crate::coordinator::worker::DEFAULT_CACHE_CAPACITY_BYTES,
            PolicyKind::Greedy,
            TraceHandle::null(),
        )
    }

    fn node(id: u32) -> Node {
        Node { id, gpu: GpuModel::A10 }
    }

    /// Interleaved two-context workload with dense ids.
    fn tasks(per_ctx: u64) -> Vec<Task> {
        let mut out = Vec::new();
        let mut id = 0;
        for i in 0..per_ctx {
            for ctx in 0..2u32 {
                out.push(Task::new(id, i * 10, 10, ctx));
                id += 1;
            }
        }
        out
    }

    fn complete(c: &mut ShardedCoordinator, d: &Dispatch, now: f64) {
        for i in 0..d.phases.len() {
            c.phase_done(d.task, i);
        }
        if d.is_prefetch() {
            return;
        }
        let (attempts, inferences) = c.task_meta(d.task).unwrap();
        let record = TaskRecord {
            task: d.task,
            context: c.task_context(d.task).unwrap(),
            worker: d.worker,
            gpu: GpuModel::A10,
            attempts,
            inferences,
            dispatched_at: now,
            completed_at: now + 1.0,
            context_s: 0.0,
            execute_s: 1.0,
        };
        c.task_done(d.task, record);
    }

    #[test]
    fn partition_is_deterministic_and_clamped() {
        let c = mk(2);
        assert_eq!(c.shard_count(), 2);
        assert_eq!(c.shard_of_ctx(0), 0);
        assert_eq!(c.shard_of_ctx(1), 1);
        assert_eq!(c.home_shard_of_node(4), 0);
        assert_eq!(c.home_shard_of_node(7), 1);
        // More shards than contexts clamps to the registry size.
        assert_eq!(mk(8).shard_count(), 2);
        assert_eq!(mk(0).shard_count(), 1);
    }

    #[test]
    fn worker_ids_are_globally_unique_across_shards() {
        let mut c = mk(2);
        let ids: Vec<WorkerId> =
            (0..6).map(|i| c.worker_join(node(i), 0.0)).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 6, "no id reused across shards: {ids:?}");
        assert_eq!(c.connected_workers(), 6);
        assert!(c.check_index_consistency());
    }

    #[test]
    fn tasks_route_to_their_context_shard_and_complete() {
        let mut c = mk(2);
        c.submit_tasks(tasks(2));
        assert_eq!(c.ready_count(), 4);
        for i in 0..4 {
            c.worker_join(node(i), 0.0);
        }
        let mut now = 0.0;
        while !c.all_done() {
            let ds = c.dispatch_all(now);
            assert!(
                !ds.is_empty() || c.running_count() > 0,
                "stalled with {} ready",
                c.ready_count()
            );
            for d in &ds {
                complete(&mut c, d, now);
            }
            now += 10.0;
            assert!(c.check_conservation());
            assert!(c.check_index_consistency());
        }
        assert_eq!(c.progress().completed_tasks, 4);
        let recs = c.records();
        assert_eq!(recs.len(), 4);
        // Two contexts' tasks each completed on their own shard's
        // workers (home partition: even nodes → shard 0, odd → 1).
        for r in &recs {
            let wnode = r.worker as u32; // join order = node order here
            assert_eq!(
                c.shard_of_ctx(r.context),
                c.home_shard_of_node(wnode),
                "no steal was needed in the balanced run"
            );
        }
        assert_eq!(c.steals(), 0);
    }

    #[test]
    fn backlogged_shard_borrows_idle_workers_and_returns_them() {
        let mut c = mk(2);
        // Ctx 0 (shard 0) has a deep backlog; ctx 1 (shard 1) has none.
        let work: Vec<Task> =
            (0..8).map(|i| Task::new(i, i * 10, 10, 0)).collect();
        c.submit_tasks(work);
        // Two workers per shard.
        for i in 0..4 {
            c.worker_join(node(i), 0.0);
        }
        let ds = c.dispatch_all(0.0);
        // Shard 0's two workers take tasks, then shard 1's idle pair is
        // lent over and dispatched too.
        assert_eq!(ds.len(), 4, "all four workers busy: {ds:?}");
        assert_eq!(c.steals(), 2, "both idle workers were lent");
        assert!(c.check_index_consistency());
        let mut now = 10.0;
        while !c.all_done() {
            let ds: Vec<Dispatch> = c.dispatch_all(now);
            for d in &ds {
                complete(&mut c, d, now);
            }
            // Completing frees workers; drive the next round.
            now += 10.0;
            if c.running_count() == 0 && c.ready_count() == 0 {
                break;
            }
            let pending: Vec<Dispatch> = c.dispatch_all(now);
            for d in &pending {
                complete(&mut c, d, now);
            }
            now += 10.0;
        }
        assert_eq!(c.progress().completed_tasks, 8);
        // With the backlog drained, every lent worker went home.
        let final_round = c.dispatch_all(now);
        assert!(final_round.is_empty());
        for i in 0..4u32 {
            let wid = c.worker_on_node(i).unwrap();
            assert_eq!(
                *c.worker_shard.get(&wid).unwrap(),
                c.home_shard_of_node(i),
                "worker on node {i} is back home"
            );
        }
        assert!(c.check_index_consistency());
    }

    #[test]
    fn evicting_a_lent_worker_migrates_the_node_snapshot_home() {
        let mut c = mk(2);
        // Only ctx 0 has work: node 1's worker (home shard 1) is lent
        // to shard 0 and stages ctx 0 bytes there.
        let work: Vec<Task> =
            (0..4).map(|i| Task::new(i, i * 10, 10, 0)).collect();
        c.submit_tasks(work);
        let w0 = c.worker_join(node(0), 0.0);
        let w1 = c.worker_join(node(1), 0.0);
        let ds = c.dispatch_all(0.0);
        assert_eq!(ds.len(), 2);
        assert_eq!(c.steals(), 1, "node 1's worker was lent to shard 0");
        assert_eq!(*c.worker_shard.get(&w1).unwrap(), 0);
        // Finish the staging phases so the lent worker holds cache
        // bytes, then evict it mid-run (away from home).
        for d in &ds {
            for (i, p) in d.phases.iter().enumerate() {
                c.phase_done(d.task, i);
                if matches!(p, PhaseKind::Materialize { .. }) {
                    break; // cache + library resident; task still running
                }
            }
        }
        assert!(c.worker(w1).unwrap().cached_bytes_total() > 0);
        c.worker_evict(w1);
        // The snapshot must live in shard 1's ledger (node 1's home),
        // not shard 0's: a rejoin of node 1 goes through shard 1.
        assert!(c.shards[0].node_caches().entry(1).is_none());
        assert!(c.shards[1].node_caches().entry(1).is_some());
        // And the rejoin warm-starts from it.
        let w1b = c.worker_join(node(1), 1.0);
        assert!(c.worker(w1b).unwrap().warm_started());
        assert!(c.check_index_consistency());
        let _ = w0;
    }

    #[test]
    fn single_shard_routes_everything_to_shard_zero() {
        let mut c = mk(1);
        c.submit_tasks(tasks(3));
        for i in 0..3 {
            c.worker_join(node(i), 0.0);
        }
        assert_eq!(c.shard_of_ctx(0), 0);
        assert_eq!(c.shard_of_ctx(1), 0);
        let ds = c.dispatch_all(0.0);
        assert_eq!(ds.len(), 3);
        assert_eq!(c.steals(), 0);
        assert!(c.shards[0].shard_id().is_none(), "unsharded trace shape");
    }

    #[test]
    fn stealing_can_be_disabled_for_parity_runs() {
        let mut c = mk(2);
        c.set_stealing(false);
        // Ctx 0 (shard 0) backlogged, shard 1's workers idle: with the
        // steal pass off, the idle pair stays home and unused.
        let work: Vec<Task> =
            (0..8).map(|i| Task::new(i, i * 10, 10, 0)).collect();
        c.submit_tasks(work);
        for i in 0..4 {
            c.worker_join(node(i), 0.0);
        }
        let ds = c.dispatch_all(0.0);
        assert_eq!(ds.len(), 2, "only shard 0's own workers dispatch");
        assert_eq!(c.steals(), 0);
        assert!(c.check_index_consistency());
        // Re-enabling brings the lend pass back on the next round.
        c.set_stealing(true);
        let ds = c.dispatch_all(1.0);
        assert_eq!(ds.len(), 2, "shard 1's idle pair is lent over");
        assert_eq!(c.steals(), 2);
    }

    #[test]
    fn into_parts_reassemble_round_trips_mid_run_state() {
        let mut c = mk(2);
        c.submit_tasks(tasks(2));
        for i in 0..4 {
            c.worker_join(node(i), 0.0);
        }
        let ds = c.dispatch_all(0.0);
        for d in &ds {
            complete(&mut c, d, 0.0);
        }
        let done_before = c.progress().completed_tasks;
        let steals_before = c.steals();
        let next_before = c.next_worker_id;

        // Dismember mid-run (as the threaded runtime does), mutate a
        // shard directly (as a shard thread would), reassemble.
        let mut parts = c.into_parts();
        assert_eq!(parts.shards.len(), 2);
        assert_eq!(parts.next_worker_id, next_before);
        parts.shards[0].set_clock_hint(5.0);
        let mut c = ShardedCoordinator::reassemble(parts);
        assert_eq!(c.progress().completed_tasks, done_before);
        assert_eq!(c.steals(), steals_before);
        assert_eq!(c.next_worker_id, next_before);
        assert!(c.check_conservation());
        assert!(c.check_index_consistency());

        // And the reassembled coordinator keeps scheduling.
        let mut now = 10.0;
        while !c.all_done() {
            let ds = c.dispatch_all(now);
            assert!(!ds.is_empty() || c.running_count() > 0);
            for d in &ds {
                complete(&mut c, d, now);
            }
            now += 10.0;
        }
        assert_eq!(c.progress().completed_tasks, 4);
        assert_eq!(c.records().len(), 4);
    }
}
