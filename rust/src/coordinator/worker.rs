//! Workers: the base unit of opportunistic resource acquisition.
//!
//! Per the paper's policy (§5.3.2) each worker is minimal — 1 GPU, runs
//! at most **one task at a time** — so evictions lose fine-grained chunks
//! and fast GPUs naturally pull more tasks (the heterogeneity answer to
//! Challenge #4). A worker owns a local cache of context components and
//! at most one library process.

use std::collections::HashSet;

use super::context::{ComponentKind, ContextId};
use super::library::LibraryState;
use super::task::TaskId;
use crate::cluster::{GpuModel, Node, NodeId};

/// Dense worker identifier (never reused within a run).
pub type WorkerId = u32;

/// One connected worker.
#[derive(Debug, Clone)]
pub struct Worker {
    pub id: WorkerId,
    pub node: Node,
    pub joined_at: f64,
    /// Context components staged in the local cache (survives tasks under
    /// Partial/Pervasive; wiped with the worker on eviction).
    cache: HashSet<(ContextId, ComponentKind)>,
    /// The (single) library process.
    pub library: LibraryState,
    /// Currently running task, if any (1-to-1 task:worker policy).
    pub running: Option<TaskId>,
    /// Peer-transfer source slots in use (fan-out cap enforcement).
    pub active_uploads: u32,
    pub tasks_completed: u64,
    pub inferences_completed: u64,
}

impl Worker {
    pub fn new(id: WorkerId, node: Node, joined_at: f64) -> Self {
        Self {
            id,
            node,
            joined_at,
            cache: HashSet::new(),
            library: LibraryState::Absent,
            running: None,
            active_uploads: 0,
            tasks_completed: 0,
            inferences_completed: 0,
        }
    }

    pub fn node_id(&self) -> NodeId {
        self.node.id
    }

    pub fn gpu(&self) -> GpuModel {
        self.node.gpu
    }

    pub fn relative_speed(&self) -> f64 {
        self.node.relative_speed()
    }

    pub fn is_idle(&self) -> bool {
        self.running.is_none()
    }

    // ---------------------------------------------------------- cache ops

    pub fn has_cached(&self, ctx: ContextId, kind: ComponentKind) -> bool {
        self.cache.contains(&(ctx, kind))
    }

    pub fn insert_cached(&mut self, ctx: ContextId, kind: ComponentKind) {
        self.cache.insert((ctx, kind));
    }

    pub fn cached_count(&self) -> usize {
        self.cache.len()
    }

    /// Drop per-task sandbox state (None policy caches nothing anyway;
    /// this models the sandbox teardown of §5.2 observation 3).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
    }

    // ------------------------------------------------------ transfer slots

    /// Try to claim an upload slot (peer-transfer source), capped at
    /// `fanout_cap` concurrent transfers per worker (§5.3.1).
    pub fn try_claim_upload(&mut self, fanout_cap: u32) -> bool {
        if self.active_uploads < fanout_cap {
            self.active_uploads += 1;
            true
        } else {
            false
        }
    }

    pub fn release_upload(&mut self) {
        debug_assert!(self.active_uploads > 0);
        self.active_uploads = self.active_uploads.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    fn worker() -> Worker {
        Worker::new(0, Node { id: 3, gpu: GpuModel::A10 }, 5.0)
    }

    #[test]
    fn fresh_worker_is_idle_and_empty() {
        let w = worker();
        assert!(w.is_idle());
        assert_eq!(w.cached_count(), 0);
        assert_eq!(w.library, LibraryState::Absent);
        assert_eq!(w.node_id(), 3);
        assert_eq!(w.relative_speed(), 1.0);
    }

    #[test]
    fn cache_roundtrip() {
        let mut w = worker();
        w.insert_cached(0, ComponentKind::DepsPackage);
        assert!(w.has_cached(0, ComponentKind::DepsPackage));
        assert!(!w.has_cached(0, ComponentKind::ModelWeights));
        assert!(!w.has_cached(1, ComponentKind::DepsPackage));
        w.clear_cache();
        assert_eq!(w.cached_count(), 0);
    }

    #[test]
    fn upload_slots_respect_cap() {
        let mut w = worker();
        assert!(w.try_claim_upload(2));
        assert!(w.try_claim_upload(2));
        assert!(!w.try_claim_upload(2));
        w.release_upload();
        assert!(w.try_claim_upload(2));
    }
}
