//! Workers: the base unit of opportunistic resource acquisition.
//!
//! Per the paper's policy (§5.3.2) each worker is minimal — 1 GPU, runs
//! at most **one task at a time** — so evictions lose fine-grained chunks
//! and fast GPUs naturally pull more tasks (the heterogeneity answer to
//! Challenge #4). A worker owns a local cache of context components and
//! at most one library process.
//!
//! The cache is **finite**: a worker slot ships with ~70 GB of scratch
//! disk (§5.3.2), so under multi-application serving the cached contexts
//! genuinely compete for space. Eviction is LRU at *context* granularity
//! (a half-evicted context is worthless — the next task would re-stage
//! the missing half anyway), and a context needed by the worker's
//! in-flight task is pinned and never evicted.
//!
//! **Two tiers.** A worker's context state splits along what survives a
//! cluster reclamation:
//!
//! * the **volatile tier** — the materialized [`LibraryState`] (model in
//!   GPU memory, the running library process). Dies with the worker, no
//!   exceptions.
//! * the **disk tier** — the staged component files in `cache`. These
//!   live on the *node's* scratch disk, not in the worker process, so a
//!   reclamation only orphans them: the scheduler snapshots them into a
//!   [`super::nodecache::NodeCacheDirectory`] keyed by node id at
//!   eviction, and a worker rejoining the same node warm-starts from the
//!   snapshot instead of re-staging gigabytes (paper §7 future work).
//!
//! Each cached context carries the recipe `version` it was staged at, so
//! a warm start can refuse entries the registry has since superseded.

use std::collections::HashMap;

use super::context::{ComponentKind, ContextId};
use super::library::LibraryState;
use super::task::TaskId;
use crate::cluster::{GpuModel, Node, NodeId};

/// Dense worker identifier (never reused within a run).
pub type WorkerId = u32;

/// Default per-worker cache capacity: the ~70 GB scratch disk of the
/// paper's worker sizing policy (§5.3.2).
pub const DEFAULT_CACHE_CAPACITY_BYTES: u64 = 70_000_000_000;

/// One connected worker.
#[derive(Debug, Clone)]
pub struct Worker {
    pub id: WorkerId,
    pub node: Node,
    pub joined_at: f64,
    /// Context components staged in the local cache, with their sizes
    /// (survives tasks under Partial/Pervasive; wiped with the worker on
    /// cluster eviction).
    cache: HashMap<(ContextId, ComponentKind), u64>,
    cache_used: u64,
    cache_capacity: u64,
    /// Last-use stamp per context with cached bytes (LRU bookkeeping).
    lru: HashMap<ContextId, u64>,
    /// Recipe version each cached context was staged at (disk-tier
    /// provenance; consulted when persisting to the node directory).
    ctx_versions: HashMap<ContextId, u32>,
    clock: u64,
    /// Components restored from the node-resident disk cache at join
    /// time (0 = this worker cold-started).
    pub warm_start_components: u64,
    /// The (single) library process.
    pub library: LibraryState,
    /// Currently running task, if any (1-to-1 task:worker policy).
    pub running: Option<TaskId>,
    /// Peer-transfer source slots in use (fan-out cap enforcement).
    pub active_uploads: u32,
    pub tasks_completed: u64,
    pub inferences_completed: u64,
}

impl Worker {
    pub fn new(
        id: WorkerId,
        node: Node,
        joined_at: f64,
        cache_capacity: u64,
    ) -> Self {
        Self {
            id,
            node,
            joined_at,
            cache: HashMap::new(),
            cache_used: 0,
            cache_capacity,
            lru: HashMap::new(),
            ctx_versions: HashMap::new(),
            clock: 0,
            warm_start_components: 0,
            library: LibraryState::Absent,
            running: None,
            active_uploads: 0,
            tasks_completed: 0,
            inferences_completed: 0,
        }
    }

    pub fn node_id(&self) -> NodeId {
        self.node.id
    }

    pub fn gpu(&self) -> GpuModel {
        self.node.gpu
    }

    pub fn relative_speed(&self) -> f64 {
        self.node.relative_speed()
    }

    pub fn is_idle(&self) -> bool {
        self.running.is_none()
    }

    // ---------------------------------------------------------- cache ops

    pub fn has_cached(&self, ctx: ContextId, kind: ComponentKind) -> bool {
        self.cache.contains_key(&(ctx, kind))
    }

    /// Number of cached components (across all contexts).
    pub fn cached_count(&self) -> usize {
        self.cache.len()
    }

    /// Bytes cached for one context.
    pub fn cached_bytes(&self, ctx: ContextId) -> u64 {
        self.cache
            .iter()
            .filter(|((c, _), _)| *c == ctx)
            .map(|(_, b)| *b)
            .sum()
    }

    /// Total cache occupancy in bytes (the capacity invariant's subject).
    pub fn cached_bytes_total(&self) -> u64 {
        self.cache_used
    }

    /// Component kinds currently cached for `ctx` (unordered) — lets
    /// from-scratch referees (index-consistency checks, golden-parity
    /// reference ports) recompute pool-wide peer availability from
    /// public worker state alone.
    pub fn cached_kinds(
        &self,
        ctx: ContextId,
    ) -> impl Iterator<Item = ComponentKind> + '_ {
        self.cache
            .keys()
            .filter(move |(c, _)| *c == ctx)
            .map(|(_, k)| *k)
    }

    pub fn cache_capacity(&self) -> u64 {
        self.cache_capacity
    }

    /// Snapshot iterator over the disk tier: every cached component with
    /// its byte size (the node-cache directory persists exactly this).
    pub fn cache_contents(
        &self,
    ) -> impl Iterator<Item = ((ContextId, ComponentKind), u64)> + '_ {
        self.cache.iter().map(|(k, b)| (*k, *b))
    }

    /// Recipe version `ctx`'s cached components were staged at (0 when
    /// nothing recorded — pre-versioning entries).
    pub fn cached_version(&self, ctx: ContextId) -> u32 {
        self.ctx_versions.get(&ctx).copied().unwrap_or(0)
    }

    /// Record the recipe version `ctx`'s cached bytes belong to.
    pub fn set_cached_version(&mut self, ctx: ContextId, version: u32) {
        self.ctx_versions.insert(ctx, version);
    }

    /// Did this worker warm-start from a node-resident cache at join?
    pub fn warm_started(&self) -> bool {
        self.warm_start_components > 0
    }

    /// Invalidate every cached component of `ctx` (registry version
    /// bump: the bytes on disk no longer match the recipe). Returns the
    /// bytes freed. Not counted as an LRU eviction — this is
    /// invalidation, not capacity pressure.
    pub fn drop_context(&mut self, ctx: ContextId) -> u64 {
        let before = self.cache_used;
        self.evict_context(ctx);
        before - self.cache_used
    }

    /// Mark `ctx` as recently used (dispatch of one of its tasks).
    pub fn touch_context(&mut self, ctx: ContextId) {
        self.clock += 1;
        if let Some(stamp) = self.lru.get_mut(&ctx) {
            *stamp = self.clock;
        }
    }

    /// Insert one staged component, evicting least-recently-used *cold*
    /// contexts wholesale until it fits. `pinned` (the context of the
    /// worker's in-flight task) is never evicted, and neither is `ctx`
    /// itself. Returns whether the component was cached plus the list of
    /// contexts evicted to make room; if nothing evictable remains and
    /// the component still does not fit, it is simply not cached (the
    /// next task of that context re-stages it — correct, just slower).
    pub fn insert_cached(
        &mut self,
        ctx: ContextId,
        kind: ComponentKind,
        bytes: u64,
        pinned: Option<ContextId>,
    ) -> (bool, Vec<ContextId>) {
        let mut evicted = Vec::new();
        if self.cache.contains_key(&(ctx, kind)) {
            self.touch_context(ctx);
            return (true, evicted);
        }
        if bytes > self.cache_capacity {
            return (false, evicted);
        }
        while self.cache_used.saturating_add(bytes) > self.cache_capacity {
            let victim = self
                .lru
                .iter()
                .filter(|(c, _)| **c != ctx && Some(**c) != pinned)
                .min_by_key(|(c, stamp)| (**stamp, **c))
                .map(|(c, _)| *c);
            let Some(victim) = victim else {
                return (false, evicted);
            };
            self.evict_context(victim);
            evicted.push(victim);
        }
        self.cache.insert((ctx, kind), bytes);
        self.cache_used += bytes;
        self.clock += 1;
        self.lru.insert(ctx, self.clock);
        (true, evicted)
    }

    /// Drop every cached component of `ctx`.
    fn evict_context(&mut self, ctx: ContextId) {
        let freed: u64 = self
            .cache
            .iter()
            .filter(|((c, _), _)| *c == ctx)
            .map(|(_, b)| *b)
            .sum();
        self.cache.retain(|(c, _), _| *c != ctx);
        self.cache_used -= freed;
        self.lru.remove(&ctx);
        self.ctx_versions.remove(&ctx);
    }

    /// Contexts currently holding cached bytes, LRU-first (for tests and
    /// observability).
    pub fn cached_contexts_lru(&self) -> Vec<ContextId> {
        let mut v: Vec<(ContextId, u64)> =
            self.lru.iter().map(|(c, s)| (*c, *s)).collect();
        v.sort_by_key(|(c, s)| (*s, *c));
        v.into_iter().map(|(c, _)| c).collect()
    }

    /// Drop per-task sandbox state (None policy caches nothing anyway;
    /// this models the sandbox teardown of §5.2 observation 3).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
        self.lru.clear();
        self.ctx_versions.clear();
        self.cache_used = 0;
    }

    // ------------------------------------------------------ transfer slots

    /// Try to claim an upload slot (peer-transfer source), capped at
    /// `fanout_cap` concurrent transfers per worker (§5.3.1).
    pub fn try_claim_upload(&mut self, fanout_cap: u32) -> bool {
        if self.active_uploads < fanout_cap {
            self.active_uploads += 1;
            true
        } else {
            false
        }
    }

    pub fn release_upload(&mut self) {
        debug_assert!(self.active_uploads > 0);
        self.active_uploads = self.active_uploads.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    fn worker() -> Worker {
        Worker::new(
            0,
            Node { id: 3, gpu: GpuModel::A10 },
            5.0,
            DEFAULT_CACHE_CAPACITY_BYTES,
        )
    }

    fn small_worker(capacity: u64) -> Worker {
        Worker::new(0, Node { id: 0, gpu: GpuModel::A10 }, 0.0, capacity)
    }

    #[test]
    fn fresh_worker_is_idle_and_empty() {
        let w = worker();
        assert!(w.is_idle());
        assert_eq!(w.cached_count(), 0);
        assert_eq!(w.cached_bytes_total(), 0);
        assert_eq!(w.library, LibraryState::Absent);
        assert_eq!(w.node_id(), 3);
        assert_eq!(w.relative_speed(), 1.0);
    }

    #[test]
    fn cache_roundtrip() {
        let mut w = worker();
        w.insert_cached(0, ComponentKind::DepsPackage, 100, None);
        assert!(w.has_cached(0, ComponentKind::DepsPackage));
        assert!(!w.has_cached(0, ComponentKind::ModelWeights));
        assert!(!w.has_cached(1, ComponentKind::DepsPackage));
        assert_eq!(w.cached_bytes(0), 100);
        assert_eq!(w.cached_bytes_total(), 100);
        w.clear_cache();
        assert_eq!(w.cached_count(), 0);
        assert_eq!(w.cached_bytes_total(), 0);
    }

    #[test]
    fn duplicate_insert_does_not_double_count() {
        let mut w = worker();
        let (ok, _) = w.insert_cached(0, ComponentKind::ModelWeights, 50, None);
        assert!(ok);
        let (ok, _) = w.insert_cached(0, ComponentKind::ModelWeights, 50, None);
        assert!(ok);
        assert_eq!(w.cached_bytes_total(), 50);
    }

    #[test]
    fn lru_evicts_coldest_context_wholesale() {
        let mut w = small_worker(100);
        w.insert_cached(0, ComponentKind::DepsPackage, 30, None);
        w.insert_cached(0, ComponentKind::ModelWeights, 30, None);
        w.insert_cached(1, ComponentKind::DepsPackage, 30, None);
        // Touch ctx 0 so ctx 1 is the cold one.
        w.touch_context(0);
        let (ok, evicted) =
            w.insert_cached(2, ComponentKind::ModelWeights, 35, None);
        assert!(ok);
        assert_eq!(evicted, vec![1]);
        // Context 1 is gone entirely; 0 and 2 survive.
        assert!(!w.has_cached(1, ComponentKind::DepsPackage));
        assert!(w.has_cached(0, ComponentKind::DepsPackage));
        assert!(w.has_cached(0, ComponentKind::ModelWeights));
        assert!(w.has_cached(2, ComponentKind::ModelWeights));
        assert!(w.cached_bytes_total() <= w.cache_capacity());
    }

    #[test]
    fn pinned_context_survives_pressure() {
        let mut w = small_worker(100);
        w.insert_cached(7, ComponentKind::ModelWeights, 60, Some(7));
        // Inserting a huge component for ctx 8 cannot evict pinned 7, so
        // it fails to cache and occupancy stays within capacity.
        let (ok, evicted) =
            w.insert_cached(8, ComponentKind::ModelWeights, 60, Some(7));
        assert!(!ok);
        assert!(evicted.is_empty());
        assert!(w.has_cached(7, ComponentKind::ModelWeights));
        assert!(w.cached_bytes_total() <= w.cache_capacity());
    }

    #[test]
    fn oversized_component_never_caches() {
        let mut w = small_worker(10);
        let (ok, evicted) =
            w.insert_cached(0, ComponentKind::ModelWeights, 11, None);
        assert!(!ok && evicted.is_empty());
        assert_eq!(w.cached_bytes_total(), 0);
    }

    #[test]
    fn versions_tracked_and_dropped_with_context() {
        let mut w = worker();
        w.insert_cached(3, ComponentKind::ModelWeights, 100, None);
        assert_eq!(w.cached_version(3), 0, "unrecorded version reads 0");
        w.set_cached_version(3, 2);
        assert_eq!(w.cached_version(3), 2);
        let freed = w.drop_context(3);
        assert_eq!(freed, 100);
        assert!(!w.has_cached(3, ComponentKind::ModelWeights));
        assert_eq!(w.cached_version(3), 0, "version dies with the context");
        assert_eq!(w.drop_context(3), 0, "double drop is a no-op");
    }

    #[test]
    fn cache_contents_snapshots_the_disk_tier() {
        let mut w = worker();
        w.insert_cached(0, ComponentKind::DepsPackage, 10, None);
        w.insert_cached(1, ComponentKind::ModelWeights, 20, None);
        let mut snap: Vec<_> = w.cache_contents().collect();
        snap.sort();
        assert_eq!(
            snap,
            vec![
                ((0, ComponentKind::DepsPackage), 10),
                ((1, ComponentKind::ModelWeights), 20)
            ]
        );
        assert!(!w.warm_started());
    }

    #[test]
    fn upload_slots_respect_cap() {
        let mut w = worker();
        assert!(w.try_claim_upload(2));
        assert!(w.try_claim_upload(2));
        assert!(!w.try_claim_upload(2));
        w.release_upload();
        assert!(w.try_claim_upload(2));
    }
}
