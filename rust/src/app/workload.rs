//! Inference workloads: the bridge between the application (claims +
//! prompt template) and the coordinator (opaque inference indices).
//!
//! The scheduler batches *indices*; only when a task executes in live
//! mode does the workload render index → prompt text. In simulated mode
//! the texts are never materialized — the cost model only needs counts —
//! which is what lets the 150 k-inference experiments run in milliseconds.

use super::fever::{FeverDataset, Label};
use super::prompts::PromptTemplate;

/// A (dataset, template) pair presented as an indexable prompt stream.
#[derive(Debug, Clone)]
pub struct InferenceWorkload {
    dataset: FeverDataset,
    template: PromptTemplate,
}

impl InferenceWorkload {
    pub fn new(dataset: FeverDataset, template: PromptTemplate) -> Self {
        Self { dataset, template }
    }

    /// The paper's workload: 150 k prompts, Direct template.
    pub fn paper(seed: u64) -> Self {
        Self::new(FeverDataset::paper_workload(seed), PromptTemplate::Direct)
    }

    pub fn len(&self) -> u64 {
        self.dataset.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.dataset.is_empty()
    }

    pub fn template(&self) -> PromptTemplate {
        self.template
    }

    pub fn dataset(&self) -> &FeverDataset {
        &self.dataset
    }

    /// Render the prompt for inference index `i`.
    pub fn prompt(&self, i: u64) -> String {
        self.template.render(self.dataset.claim(i))
    }

    /// Ground-truth label for inference index `i`.
    pub fn label(&self, i: u64) -> Label {
        self.dataset.claim(i).label
    }

    /// Render a contiguous batch of prompts `[start, start+count)`.
    pub fn prompt_batch(&self, start: u64, count: u64) -> Vec<String> {
        (start..start + count).map(|i| self.prompt(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompts_render_per_template() {
        let w = InferenceWorkload::new(
            FeverDataset::generate(10, 0),
            PromptTemplate::WithEvidence,
        );
        assert_eq!(w.len(), 10);
        let p = w.prompt(3);
        assert!(p.contains("EVIDENCE:"));
    }

    #[test]
    fn batch_is_contiguous() {
        let w = InferenceWorkload::new(
            FeverDataset::generate(20, 1),
            PromptTemplate::Direct,
        );
        let batch = w.prompt_batch(5, 4);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0], w.prompt(5));
        assert_eq!(batch[3], w.prompt(8));
    }

    #[test]
    fn labels_align_with_dataset() {
        let d = FeverDataset::generate(10, 2);
        let w = InferenceWorkload::new(d.clone(), PromptTemplate::Direct);
        for i in 0..10 {
            assert_eq!(w.label(i), d.claim(i).label);
        }
    }
}
