//! Prompt templates — the search axis of the PfF application.
//!
//! PfF "seeks to find an optimal pair of (LLM, prompt template) that
//! yields the highest accuracy" (§6.1). Each template renders a claim
//! (and optionally its evidence) into the prompt string the model
//! classifies.

use super::fever::Claim;

/// A named prompt-rendering strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PromptTemplate {
    /// Bare claim, minimal framing.
    Direct,
    /// Claim + instruction framing.
    Instructed,
    /// Claim + resolved evidence (the Wikipedia join).
    WithEvidence,
    /// Chain-of-thought-style framing.
    StepByStep,
}

impl PromptTemplate {
    pub const ALL: [PromptTemplate; 4] = [
        PromptTemplate::Direct,
        PromptTemplate::Instructed,
        PromptTemplate::WithEvidence,
        PromptTemplate::StepByStep,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PromptTemplate::Direct => "direct",
            PromptTemplate::Instructed => "instructed",
            PromptTemplate::WithEvidence => "with-evidence",
            PromptTemplate::StepByStep => "step-by-step",
        }
    }

    /// Render a claim into the model's input text.
    pub fn render(&self, claim: &Claim) -> String {
        match self {
            PromptTemplate::Direct => {
                format!("CLAIM: {} VERDICT:", claim.text)
            }
            PromptTemplate::Instructed => format!(
                "You are a fact verifier. Decide if the claim is SUPPORTED, \
                 REFUTED or NOT ENOUGH INFO. CLAIM: {} VERDICT:",
                claim.text
            ),
            PromptTemplate::WithEvidence => format!(
                "EVIDENCE: {} CLAIM: {} VERDICT:",
                claim.evidence, claim.text
            ),
            PromptTemplate::StepByStep => format!(
                "Verify step by step, then answer. CLAIM: {} Think about \
                 the subject, the predicate, and the evidence. VERDICT:",
                claim.text
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::fever::{FeverDataset, Label};

    fn claim() -> Claim {
        FeverDataset::generate(1, 0).claim(0).clone()
    }

    #[test]
    fn all_templates_render_claim_text() {
        let c = claim();
        for t in PromptTemplate::ALL {
            let p = t.render(&c);
            assert!(p.contains(&c.text), "{t:?}");
            assert!(p.contains("VERDICT:"), "{t:?}");
        }
    }

    #[test]
    fn with_evidence_includes_evidence() {
        let c = claim();
        let p = PromptTemplate::WithEvidence.render(&c);
        assert!(p.contains(&c.evidence));
        assert!(!PromptTemplate::Direct.render(&c).contains("EVIDENCE"));
    }

    #[test]
    fn templates_render_differently() {
        let c = claim();
        let rendered: Vec<String> =
            PromptTemplate::ALL.iter().map(|t| t.render(&c)).collect();
        for i in 0..rendered.len() {
            for j in (i + 1)..rendered.len() {
                assert_ne!(rendered[i], rendered[j]);
            }
        }
    }

    #[test]
    fn empty_control_claim_renders() {
        let c = Claim {
            id: 0,
            text: String::new(),
            label: Label::NotEnoughInfo,
            evidence: String::new(),
            is_control: true,
        };
        let p = PromptTemplate::Direct.render(&c);
        assert!(p.contains("VERDICT:"));
    }
}
