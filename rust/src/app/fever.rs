//! Synthetic FEVER-like fact-verification dataset.
//!
//! The paper sweeps the FEVER training split: 145,449 labeled claims
//! (SUPPORTED / REFUTED / NOT ENOUGH INFO), each referencing Wikipedia
//! pages that the authors pre-join into a local database (§6.2). We
//! cannot redistribute FEVER, so this generator builds a deterministic
//! synthetic stand-in with the same cardinality, label structure, and
//! preprocessing step (reference resolution). The coordinator and the
//! model runtime only ever see `(text, label)` pairs, so scheduling and
//! throughput behaviour are unaffected by the substitution (DESIGN.md).

use crate::util::Rng;

/// FEVER's three verdict labels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Label {
    Supported,
    Refuted,
    NotEnoughInfo,
}

impl Label {
    pub fn as_str(&self) -> &'static str {
        match self {
            Label::Supported => "SUPPORTED",
            Label::Refuted => "REFUTED",
            Label::NotEnoughInfo => "NOT ENOUGH INFO",
        }
    }

    pub fn class_index(&self) -> usize {
        match self {
            Label::Supported => 0,
            Label::Refuted => 1,
            Label::NotEnoughInfo => 2,
        }
    }
}

/// One claim, post reference-resolution.
#[derive(Debug, Clone)]
pub struct Claim {
    pub id: u64,
    pub text: String,
    pub label: Label,
    /// Resolved evidence snippet (the paper's Wikipedia join output).
    pub evidence: String,
    /// Control-group marker (the paper injects "a small number of empty
    /// claims as the control group", §6.2).
    pub is_control: bool,
}

/// Subject/predicate vocabularies for the synthetic generator.
const SUBJECTS: &[&str] = &[
    "Barack Obama", "the Eiffel Tower", "the Pacific Ocean", "Mount Everest",
    "the Great Wall", "Marie Curie", "the Amazon River", "Isaac Newton",
    "the Sahara Desert", "Leonardo da Vinci", "the Moon", "Antarctica",
    "the Nile", "Albert Einstein", "the Colosseum", "Jupiter",
];
const PREDICATES_TRUE: &[&str] = &[
    "is a well documented subject", "appears in encyclopedias",
    "has been photographed", "is studied by researchers",
];
const PREDICATES_FALSE: &[&str] = &[
    "is made entirely of glass", "was built in 1999 by robots",
    "orbits the Sun backwards", "is smaller than a coin",
];
const PREDICATES_UNK: &[&str] = &[
    "prefers winter to summer", "once considered a career change",
    "is rumored to inspire poets", "may appear in a future film",
];

/// The dataset: deterministic per seed, FEVER-sized by default.
#[derive(Debug, Clone)]
pub struct FeverDataset {
    claims: Vec<Claim>,
}

impl FeverDataset {
    /// FEVER training-split cardinality (§6.2) plus the control group
    /// rounding the workload to 150 k inferences.
    pub const FEVER_TRAIN: u64 = 145_449;
    pub const PAPER_TOTAL: u64 = 150_000;

    /// Generate `n` claims (seeded). Labels are ~uniform; control claims
    /// (empty text) fill indices ≥ `FEVER_TRAIN` when `n > FEVER_TRAIN`,
    /// mirroring the paper's construction.
    pub fn generate(n: u64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xFE_E7);
        let mut claims = Vec::with_capacity(n as usize);
        for id in 0..n {
            let is_control = id >= Self::FEVER_TRAIN;
            if is_control {
                claims.push(Claim {
                    id,
                    text: String::new(),
                    label: Label::NotEnoughInfo,
                    evidence: String::new(),
                    is_control: true,
                });
                continue;
            }
            let subject = SUBJECTS[rng.below(SUBJECTS.len())];
            let (pred, label) = match rng.below(3) {
                0 => (
                    PREDICATES_TRUE[rng.below(PREDICATES_TRUE.len())],
                    Label::Supported,
                ),
                1 => (
                    PREDICATES_FALSE[rng.below(PREDICATES_FALSE.len())],
                    Label::Refuted,
                ),
                _ => (
                    PREDICATES_UNK[rng.below(PREDICATES_UNK.len())],
                    Label::NotEnoughInfo,
                ),
            };
            let text = format!("{subject} {pred}");
            let evidence = format!(
                "According to reference page {}, {subject} {}.",
                rng.below(100_000),
                match label {
                    Label::Supported => pred.to_string(),
                    Label::Refuted => format!("in fact never {pred}"),
                    Label::NotEnoughInfo =>
                        "is described without further detail".to_string(),
                }
            );
            claims.push(Claim { id, text, label, evidence, is_control: false });
        }
        Self { claims }
    }

    /// The paper's exact workload: 145,449 FEVER claims + control fillers
    /// = 150 k inferences.
    pub fn paper_workload(seed: u64) -> Self {
        Self::generate(Self::PAPER_TOTAL, seed)
    }

    pub fn len(&self) -> usize {
        self.claims.len()
    }

    pub fn is_empty(&self) -> bool {
        self.claims.is_empty()
    }

    pub fn claims(&self) -> &[Claim] {
        &self.claims
    }

    pub fn claim(&self, id: u64) -> &Claim {
        &self.claims[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = FeverDataset::generate(100, 1);
        let b = FeverDataset::generate(100, 1);
        for (x, y) in a.claims().iter().zip(b.claims()) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.label, y.label);
        }
        let c = FeverDataset::generate(100, 2);
        assert!(a
            .claims()
            .iter()
            .zip(c.claims())
            .any(|(x, y)| x.text != y.text));
    }

    #[test]
    fn paper_workload_structure() {
        let d = FeverDataset::generate(150_000, 0);
        assert_eq!(d.len(), 150_000);
        let controls =
            d.claims().iter().filter(|c| c.is_control).count() as u64;
        assert_eq!(controls, 150_000 - FeverDataset::FEVER_TRAIN);
        // Control claims are empty; real claims are not.
        assert!(d.claim(149_999).text.is_empty());
        assert!(!d.claim(0).text.is_empty());
    }

    #[test]
    fn labels_roughly_balanced() {
        let d = FeverDataset::generate(30_000, 3);
        let mut counts = [0u32; 3];
        for c in d.claims() {
            counts[c.label.class_index()] += 1;
        }
        for &c in &counts {
            assert!(
                (8_000..12_000).contains(&c),
                "unbalanced labels: {counts:?}"
            );
        }
    }

    #[test]
    fn evidence_is_resolved() {
        let d = FeverDataset::generate(10, 4);
        for c in d.claims() {
            if !c.is_control {
                assert!(c.evidence.contains("reference page"));
            }
        }
    }

    #[test]
    fn label_strings() {
        assert_eq!(Label::Supported.as_str(), "SUPPORTED");
        assert_eq!(Label::NotEnoughInfo.class_index(), 2);
    }
}
