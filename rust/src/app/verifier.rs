//! PfF: the optimal-prompt-search application (§6.1).
//!
//! "PfF seeks to find an optimal pair of (LLM, prompt template) that
//! yields the highest accuracy in a particular fact verification
//! dataset." The MVP takes one (LLM, template), sweeps the dataset, and
//! returns aggregate accuracy; the search is embarrassingly parallel
//! across pairs. Live mode runs real SmolVerify inference through the
//! PJRT runtime; accuracy aggregation is identical either way.

use crate::runtime::engine::Verdict;
use crate::Result;

use super::fever::Label;
use super::prompts::PromptTemplate;
use super::workload::InferenceWorkload;

/// Aggregated accuracy for one (model, template) pair.
#[derive(Debug, Clone)]
pub struct AccuracyReport {
    pub template: PromptTemplate,
    pub total: u64,
    pub correct: u64,
    /// Confusion matrix `[truth][predicted]` over the 3 classes.
    pub confusion: [[u64; 3]; 3],
}

impl AccuracyReport {
    pub fn new(template: PromptTemplate) -> Self {
        Self { template, total: 0, correct: 0, confusion: [[0; 3]; 3] }
    }

    pub fn record(&mut self, truth: Label, predicted: Verdict) {
        let p = match predicted {
            Verdict::Supported => 0,
            Verdict::Refuted => 1,
            Verdict::NotEnoughInfo => 2,
        };
        let t = truth.class_index();
        self.confusion[t][p] += 1;
        self.total += 1;
        if t == p {
            self.correct += 1;
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }

    /// Merge a partial report (task-level results folding into the app).
    pub fn merge(&mut self, other: &AccuracyReport) {
        assert_eq!(self.template, other.template);
        self.total += other.total;
        self.correct += other.correct;
        for t in 0..3 {
            for p in 0..3 {
                self.confusion[t][p] += other.confusion[t][p];
            }
        }
    }
}

/// The PfF application driver (live-mode classification path).
pub struct PffApp {
    workload: InferenceWorkload,
}

impl PffApp {
    pub fn new(workload: InferenceWorkload) -> Self {
        Self { workload }
    }

    pub fn workload(&self) -> &InferenceWorkload {
        &self.workload
    }

    /// Score a batch of verdicts produced for `[start, start+n)`.
    pub fn score_batch(
        &self,
        start: u64,
        verdicts: &[Verdict],
    ) -> AccuracyReport {
        let mut report = AccuracyReport::new(self.workload.template());
        for (i, v) in verdicts.iter().enumerate() {
            report.record(self.workload.label(start + i as u64), *v);
        }
        report
    }

    /// Run the full sweep on a local engine (no coordinator) — the pv0
    /// "dedicated GPU" baseline in live mode.
    pub fn sweep_local(
        &self,
        engine: &crate::runtime::InferenceEngine,
        limit: Option<u64>,
    ) -> Result<AccuracyReport> {
        let n = limit.unwrap_or_else(|| self.workload.len()).min(self.workload.len());
        let mut report = AccuracyReport::new(self.workload.template());
        let chunk = 64u64;
        let mut start = 0u64;
        while start < n {
            let count = chunk.min(n - start);
            let prompts = self.workload.prompt_batch(start, count);
            let refs: Vec<&str> = prompts.iter().map(|s| s.as_str()).collect();
            let verdicts = engine.classify(&refs)?;
            report.merge(&self.score_batch(start, &verdicts));
            start += count;
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::fever::FeverDataset;

    #[test]
    fn accuracy_counts() {
        let mut r = AccuracyReport::new(PromptTemplate::Direct);
        r.record(Label::Supported, Verdict::Supported);
        r.record(Label::Refuted, Verdict::Supported);
        r.record(Label::NotEnoughInfo, Verdict::NotEnoughInfo);
        assert_eq!(r.total, 3);
        assert_eq!(r.correct, 2);
        assert!((r.accuracy() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.confusion[1][0], 1);
    }

    #[test]
    fn empty_report_zero_accuracy() {
        let r = AccuracyReport::new(PromptTemplate::Direct);
        assert_eq!(r.accuracy(), 0.0);
    }

    #[test]
    fn merge_adds_up() {
        let mut a = AccuracyReport::new(PromptTemplate::Direct);
        a.record(Label::Supported, Verdict::Supported);
        let mut b = AccuracyReport::new(PromptTemplate::Direct);
        b.record(Label::Refuted, Verdict::Refuted);
        b.record(Label::Refuted, Verdict::Supported);
        a.merge(&b);
        assert_eq!(a.total, 3);
        assert_eq!(a.correct, 2);
    }

    #[test]
    fn score_batch_aligns_labels() {
        let w = InferenceWorkload::new(
            FeverDataset::generate(10, 0),
            PromptTemplate::Direct,
        );
        let app = PffApp::new(w);
        // Predict everything as the true label of index 2..5 to check
        // offset alignment.
        let truths: Vec<Label> =
            (2..5).map(|i| app.workload().label(i)).collect();
        let verdicts: Vec<Verdict> = truths
            .iter()
            .map(|l| match l {
                Label::Supported => Verdict::Supported,
                Label::Refuted => Verdict::Refuted,
                Label::NotEnoughInfo => Verdict::NotEnoughInfo,
            })
            .collect();
        let r = app.score_batch(2, &verdicts);
        assert_eq!(r.correct, 3);
    }
}
