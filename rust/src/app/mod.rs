//! The evaluation application: Prompt-for-Fact (PfF) fact verification.

pub mod fever;
pub mod prompts;
pub mod verifier;
pub mod workload;

pub use fever::{Claim, FeverDataset, Label};
pub use prompts::PromptTemplate;
pub use verifier::{AccuracyReport, PffApp};
pub use workload::InferenceWorkload;
