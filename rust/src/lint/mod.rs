//! Self-hosted static analysis: the `pcm lint` pass.
//!
//! The crate's core invariants — every scheduler mutation traced and
//! indexed, hot paths panic-free, telemetry exhaustive over
//! [`crate::obs::TraceEvent`], JSONL schema parity, disciplined atomic
//! orderings — are enforced dynamically by the replay checker and
//! property tests. This module makes them *build-time* guarantees: a
//! zero-dependency, line/token-level scan over the crate's own sources
//! (the same hand-rolled house style as [`crate::util::Json`]), run by
//! `pcm lint [--manifest-dir rust/]` and the `static-analysis` CI job.
//!
//! Five rules, each scoped to the paths where its invariant lives:
//!
//! | rule | scope | enforces |
//! |------|-------|----------|
//! | `choke-trace` / `choke-index` | `coordinator/scheduler.rs`, `coordinator/sharded.rs` | every `pub fn(&mut self, ..)` emits through `self.trace` and touches index state |
//! | `panic-free` | `coordinator/`, `live/`, `obs/`, `cluster/` | no `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/`unimplemented!` outside tests |
//! | `trace-wildcard` | `obs/` | no `_ =>` arm in a match over `TraceEvent` |
//! | `field-parity` | `obs/event.rs` | serializer and parser agree on every JSONL field name |
//! | `atomic-ordering` | `coordinator/`, `live/`, `obs/`, `cluster/` | `Ordering::Relaxed` only on documented stop-flag sites |
//!
//! Individual findings are suppressed by reasoned allowlist comments —
//! `// pcm-lint: allow(scope) -- <reason>` — documented in [`rules`].
//! The lint must pass on its own tree (`tests/lint_selfhost.rs`), which
//! is its primary integration test.

pub mod rules;
pub mod scan;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::Context;

pub use rules::{
    check_atomic_ordering, check_choke_points, check_field_parity,
    check_panics, check_wildcard_trace_arms,
};

/// One lint diagnostic: `file:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the crate's `src/`, `/`-separated.
    pub file: String,
    /// 1-based line the finding anchors to.
    pub line: usize,
    /// Stable rule identifier, e.g. `panic-free`.
    pub rule: &'static str,
    /// Human-readable diagnostic including the fix or allow syntax.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Run every rule whose scope covers `rel` (a `/`-separated path
/// relative to `src/`) over `source`.
pub fn check_file(rel: &str, source: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    if rel == "coordinator/scheduler.rs" || rel == "coordinator/sharded.rs" {
        out.extend(check_choke_points(rel, source));
    }
    let hot = ["coordinator/", "live/", "obs/", "cluster/"]
        .iter()
        .any(|p| rel.starts_with(p));
    if hot {
        out.extend(check_panics(rel, source));
        out.extend(check_atomic_ordering(rel, source));
    }
    if rel.starts_with("obs/") {
        out.extend(check_wildcard_trace_arms(rel, source));
    }
    if rel == "obs/event.rs" {
        out.extend(check_field_parity(rel, source));
    }
    out
}

/// Lint every `.rs` file under `<manifest_dir>/src`, returning the
/// findings sorted by file and line. An empty result means the tree is
/// clean.
pub fn lint_crate(manifest_dir: &Path) -> crate::Result<Vec<Finding>> {
    let src = manifest_dir.join("src");
    let mut files = Vec::new();
    collect_sources(&src, &mut files).with_context(|| {
        format!("walking crate sources under {}", src.display())
    })?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let rel = relative_name(&src, &path);
        let source = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        out.extend(check_file(&rel, &source));
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(out)
}

fn collect_sources(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_sources(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `path` relative to `root`, with `/` separators on every platform so
/// diagnostics and rule scopes are stable.
fn relative_name(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finding_display_is_file_line_rule_message() {
        let f = Finding {
            file: "live/driver.rs".into(),
            line: 42,
            rule: "panic-free",
            message: "boom".into(),
        };
        assert_eq!(f.to_string(), "live/driver.rs:42: [panic-free] boom");
    }

    #[test]
    fn dispatch_scopes_rules_by_path() {
        let panicky = "fn f() { x.unwrap(); }\n";
        assert!(!check_file("live/driver.rs", panicky).is_empty());
        assert!(!check_file("cluster/gpu.rs", panicky).is_empty());
        // Outside the hot-path scope no rule applies.
        assert!(check_file("experiments/mod.rs", panicky).is_empty());
        assert!(check_file("lint/rules.rs", panicky).is_empty());
    }

    #[test]
    fn dispatch_runs_choke_rule_only_on_the_coordinators() {
        let src = "impl S {\n\
                   \x20   pub fn m(&mut self, n: u64) { self.x = n; }\n\
                   }\n";
        let sched = check_file("coordinator/scheduler.rs", src);
        assert!(sched.iter().any(|f| f.rule == "choke-trace"), "{sched:?}");
        let sharded = check_file("coordinator/sharded.rs", src);
        assert!(
            sharded.iter().any(|f| f.rule == "choke-trace"),
            "{sharded:?}"
        );
        let other = check_file("coordinator/batcher.rs", src);
        assert!(other.iter().all(|f| !f.rule.starts_with("choke")));
    }

    #[test]
    fn shard_routing_maps_count_as_index_state() {
        let src = "impl S {\n\
                   \x20   pub fn m(&mut self, w: u64) {\n\
                   \x20       self.trace.emit(e);\n\
                   \x20       self.worker_shard.insert(w, 0);\n\
                   \x20   }\n\
                   }\n";
        assert!(check_file("coordinator/sharded.rs", src).is_empty());
    }

    #[test]
    fn dispatch_runs_parity_rule_only_on_event_rs() {
        let src = "fn to_json() {\n\
                   \x20   let fields = vec![(\"ghost\", num_u(1))];\n\
                   }\n\
                   fn from_json(j: &Json) {}\n";
        assert!(!check_file("obs/event.rs", src).is_empty());
        assert!(check_file("obs/telemetry.rs", src).is_empty());
    }
}
