//! Line-level Rust source scanner for the lint rules.
//!
//! The rules in [`super::rules`] work on *lines*, not on a full AST —
//! the same zero-dependency, hand-rolled approach the crate takes to
//! JSON. For that to be sound, each physical line is pre-digested into
//! three views plus a test flag:
//!
//! - `code` — comments stripped, string/char-literal *contents* blanked
//!   to spaces (delimiters kept), so token searches like `.unwrap()` or
//!   `Ordering::Relaxed` can never match inside a literal or a comment;
//! - `raw` — comments stripped but string contents kept, for rules that
//!   read literals (the emit/parse field-parity rule);
//! - `comment` — the comment text on the line (`//` or `/* … */`
//!   content), where `// pcm-lint: allow(…)` annotations live;
//! - `in_test` — whether the line sits inside a `#[cfg(test)]` region,
//!   tracked by brace depth from the attribute, so test code is exempt
//!   from every rule.
//!
//! The scanner understands line and nested block comments, ordinary
//! (multi-line) strings with escapes, raw strings (`r"…"`, `r#"…"#`,
//! …), and disambiguates char literals from lifetimes. It does not try
//! to be a full lexer — it only has to be conservative enough that the
//! rules never fire on literal or comment text.

/// One scanned source line. See the module docs for the three views.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number in the scanned source.
    pub number: usize,
    /// Code with comments stripped and literal contents blanked.
    pub code: String,
    /// Code with comments stripped but string contents kept.
    pub raw: String,
    /// Comment text carried by this line (empty if none).
    pub comment: String,
    /// Inside a `#[cfg(test)]` region (attribute line included).
    pub in_test: bool,
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    /// Nested block comment at the carried depth.
    Block(u32),
    /// Ordinary string literal (may span lines).
    Str,
    /// Raw string literal with the carried number of `#`s.
    RawStr(usize),
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scan `source` into per-line views. Infallible: unterminated
/// constructs simply leave the scanner in their mode to end of input.
pub fn scan(source: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut mode = Mode::Code;
    // #[cfg(test)] region tracking: brace depth of the whole file, the
    // depth at which the current test region opened, and whether the
    // attribute was seen but its `{` not yet reached.
    let mut depth: i64 = 0;
    let mut test_depth: Option<i64> = None;
    let mut pending_test = false;

    for (idx, line) in source.lines().enumerate() {
        let chars: Vec<char> = line.chars().collect();
        let mut code = String::new();
        let mut raw = String::new();
        let mut comment = String::new();
        let started_in_test = test_depth.is_some() || pending_test;
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            match mode {
                Mode::Block(d) => {
                    if c == '*' && chars.get(i + 1) == Some(&'/') {
                        mode = if d > 1 {
                            Mode::Block(d - 1)
                        } else {
                            Mode::Code
                        };
                        i += 2;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(d + 1);
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
                Mode::Str => {
                    if c == '\\' {
                        code.push(' ');
                        raw.push(c);
                        i += 1;
                        if let Some(&e) = chars.get(i) {
                            code.push(' ');
                            raw.push(e);
                            i += 1;
                        }
                    } else if c == '"' {
                        code.push('"');
                        raw.push('"');
                        mode = Mode::Code;
                        i += 1;
                    } else {
                        code.push(' ');
                        raw.push(c);
                        i += 1;
                    }
                }
                Mode::RawStr(h) => {
                    let closes = c == '"'
                        && (0..h).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closes {
                        code.push('"');
                        raw.push('"');
                        for _ in 0..h {
                            code.push('#');
                            raw.push('#');
                        }
                        mode = Mode::Code;
                        i += 1 + h;
                    } else {
                        code.push(' ');
                        raw.push(c);
                        i += 1;
                    }
                }
                Mode::Code => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        comment.extend(&chars[i + 2..]);
                        i = chars.len();
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(1);
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        raw.push('"');
                        mode = Mode::Str;
                        i += 1;
                    } else if c == 'r'
                        && (i == 0 || !is_ident(chars[i - 1]))
                        && raw_string_hashes(&chars, i).is_some()
                    {
                        let h = raw_string_hashes(&chars, i)
                            .unwrap_or_default();
                        for k in 0..=h + 1 {
                            code.push(chars[i + k]);
                            raw.push(chars[i + k]);
                        }
                        mode = Mode::RawStr(h);
                        i += h + 2;
                    } else if c == '\'' {
                        let consumed = char_literal_len(&chars, i);
                        if consumed > 0 {
                            code.push('\'');
                            raw.push('\'');
                            for _ in 1..consumed.saturating_sub(1) {
                                code.push(' ');
                                raw.push(' ');
                            }
                            code.push('\'');
                            raw.push('\'');
                            i += consumed;
                        } else {
                            // Lifetime: keep the tick as code.
                            code.push('\'');
                            raw.push('\'');
                            i += 1;
                        }
                    } else {
                        if c == '{' {
                            if pending_test && test_depth.is_none() {
                                test_depth = Some(depth);
                                pending_test = false;
                            }
                            depth += 1;
                        } else if c == '}' {
                            depth -= 1;
                            if test_depth.is_some_and(|td| depth <= td) {
                                test_depth = None;
                            }
                        }
                        code.push(c);
                        raw.push(c);
                        if c == ']' && code.ends_with("#[cfg(test)]") {
                            pending_test = true;
                        }
                        i += 1;
                    }
                }
            }
        }
        let in_test =
            started_in_test || test_depth.is_some() || pending_test;
        out.push(Line {
            number: idx + 1,
            code,
            raw,
            comment,
            in_test,
        });
    }
    out
}

/// If a raw string starts at `chars[at]` (an `r` not preceded by an
/// identifier character), the number of `#`s in its delimiter.
fn raw_string_hashes(chars: &[char], at: usize) -> Option<usize> {
    let mut j = at + 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(hashes)
}

/// Total chars of a char literal starting at the `'` at `chars[at]`,
/// or 0 when the tick starts a lifetime instead.
fn char_literal_len(chars: &[char], at: usize) -> usize {
    match chars.get(at + 1) {
        // '\n', '\'', '\\', '\u{…}': skip the escaped character, then
        // scan to the closing quote.
        Some('\\') => {
            let mut j = at + 3;
            while j < chars.len() {
                if chars[j] == '\'' {
                    return j - at + 1;
                }
                j += 1;
            }
            chars.len() - at
        }
        // 'x' — but only with a closing quote right after (otherwise
        // it is a lifetime like 'a or '_).
        Some(_) if chars.get(at + 2) == Some(&'\'') => 3,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_are_blanked_in_code_but_kept_in_raw() {
        let l = &scan("let x = \"panic!() .unwrap()\";")[0];
        assert!(!l.code.contains("panic!"));
        assert!(!l.code.contains(".unwrap()"));
        assert!(l.raw.contains("panic!() .unwrap()"));
        assert!(l.code.contains("let x ="));
    }

    #[test]
    fn line_comments_are_captured_not_code() {
        let l = &scan("foo(); // has .unwrap() in prose")[0];
        assert!(!l.code.contains(".unwrap()"));
        assert_eq!(l.comment.trim(), "has .unwrap() in prose");
        assert!(l.code.contains("foo();"));
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let lines = scan("a /* one /* two */ still */ b\nc");
        assert!(lines[0].code.contains('a'));
        assert!(lines[0].code.contains('b'));
        assert!(lines[0].comment.contains("one"));
        assert!(lines[1].code.contains('c'));
        let lines = scan("x /* open\n.unwrap()\n*/ y");
        assert!(!lines[1].code.contains(".unwrap()"));
        assert!(lines[1].comment.contains(".unwrap()"));
        assert!(lines[2].code.contains('y'));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let l = &scan("let s = r#\"todo!() \"quoted\" \"#;")[0];
        assert!(!l.code.contains("todo!"));
        assert!(l.raw.contains("todo!()"));
        // The scanner is back in code mode after the delimiter.
        assert!(l.code.trim_end().ends_with(';'));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let l = &scan("fn f<'a>(x: &'a str) -> char { '\"' }")[0];
        // The quote char literal must not open a string.
        assert!(l.code.contains("fn f<'a>(x: &'a str)"));
        assert!(l.code.trim_end().ends_with('}'));
        let l = &scan("let c = '\\''; let d = 'x';")[0];
        assert!(l.code.contains("let d ="));
    }

    #[test]
    fn multi_line_strings_stay_blanked() {
        let lines = scan("let s = \"first\npanic!()\nlast\"; done();");
        assert!(!lines[1].code.contains("panic!"));
        assert!(lines[1].raw.contains("panic!()"));
        assert!(lines[2].code.contains("done();"));
    }

    #[test]
    fn cfg_test_region_is_flagged_to_its_closing_brace() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn helper() {}\n\
                   }\n\
                   fn after() {}";
        let lines = scan(src);
        assert!(!lines[0].in_test);
        assert!(lines[1].in_test, "attribute line is test");
        assert!(lines[2].in_test);
        assert!(lines[3].in_test);
        assert!(lines[4].in_test, "closing brace is test");
        assert!(!lines[5].in_test, "code after the region is live");
    }

    #[test]
    fn cfg_test_attr_and_brace_on_one_line() {
        let lines = scan("#[cfg(test)] mod t { fn x() {} }\nfn live() {}");
        assert!(lines[0].in_test);
        assert!(!lines[1].in_test);
    }
}
