//! The five lint rules, each a pure function over one file's source.
//!
//! Every rule has the same shape — `fn check_*(file, source) ->
//! Vec<Finding>` — so fixture tests and the crate-docs doctest can
//! drive a rule on an inline snippet exactly the way [`super::lint_crate`]
//! drives it on a file from disk. Which rule applies to which path is
//! decided by [`super::check_file`].
//!
//! # Allowlist comments
//!
//! A finding is suppressed by a *reasoned* annotation:
//!
//! ```text
//! // pcm-lint: allow(<scope>[|<scope>…]) -- <reason>
//! ```
//!
//! placed on the offending line, or in the contiguous comment block
//! directly above it (for the choke-point rule: above the `pub fn`
//! signature, doc comments included). The `-- <reason>` part is
//! mandatory — an allow without a reason is ignored. Each allow
//! suppresses exactly **one** finding per scope it names: two panics on
//! one line need two annotations.
//!
//! Scopes: `untraced`, `unindexed` (choke-point coverage), `panic`
//! (panic-free hot path), `wildcard` (no `_ =>` over `TraceEvent`),
//! `relaxed` (atomic-ordering discipline).

use std::collections::{BTreeMap, HashMap, HashSet};

use super::scan::{scan, Line};
use super::Finding;

/// Index-maintenance vocabulary of `coordinator/scheduler.rs` and
/// `coordinator/sharded.rs`: a mutating choke point must touch at least
/// one of these (directly or through the named helpers) or carry an
/// `unindexed` allow. Grown alongside the scheduler's incremental
/// indexes and the sharded coordinator's routing maps (task → shard,
/// worker → shard, worker → home, the global id allocator).
const INDEX_TOKENS: &[&str] = &[
    "self.idle",
    "self.ready",
    "self.library_warm",
    "self.cache_full",
    "self.peer_kind_counts",
    "self.running_ctx",
    "self.completed_ctx",
    "self.prefetch_ctx",
    "self.est_cache",
    "self.task_shard",
    "self.worker_shard",
    "self.home_shard",
    "self.ctx_shard",
    "self.next_worker_id",
    "enqueue_ready",
    "dequeue_ready",
    "purge_worker_indexes",
    "refresh_warmth",
    "invalidate_estimate",
    "cache_component",
    "peer_inc",
    "peer_dec",
    "dec_count",
    "dec_usize",
];

/// Panic vocabulary rejected on hot paths without a `panic` allow.
const PANIC_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Parse one comment's `pcm-lint: allow(a|b) -- reason` annotation.
/// Returns the scopes, or `None` when there is no (well-formed,
/// reasoned) annotation.
fn allow_scopes(comment: &str) -> Option<Vec<String>> {
    let marker = "pcm-lint: allow(";
    let start = comment.find(marker)? + marker.len();
    let rest = &comment[start..];
    let close = rest.find(')')?;
    let scopes: Vec<String> = rest[..close]
        .split('|')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let after = &rest[close + 1..];
    let dash = after.find("--")?;
    if after[dash + 2..].trim().is_empty() {
        return None;
    }
    (!scopes.is_empty()).then_some(scopes)
}

/// Tracks allow annotations and consumes them one finding at a time.
struct Suppressor {
    /// line → per-scope one-shot allows from that line's annotation:
    /// `allow(untraced|unindexed)` can suppress one `untraced` AND one
    /// `unindexed` finding, but never two of the same scope.
    allows: HashMap<usize, Vec<(String, bool)>>,
    /// Lines that are pure comment (no code) — the backscan walks
    /// through these, and stops at the first code line.
    comment_only: HashSet<usize>,
}

impl Suppressor {
    fn new(lines: &[Line]) -> Self {
        let mut allows = HashMap::new();
        let mut comment_only = HashSet::new();
        for l in lines {
            if let Some(scopes) = allow_scopes(&l.comment) {
                let slots: Vec<(String, bool)> =
                    scopes.into_iter().map(|s| (s, false)).collect();
                allows.insert(l.number, slots);
            }
            if l.code.trim().is_empty() && !l.comment.trim().is_empty() {
                comment_only.insert(l.number);
            }
        }
        Suppressor { allows, comment_only }
    }

    /// Consume one allow for `scope` attached to the code at `line`:
    /// on the line itself, or anywhere in the contiguous comment block
    /// directly above it. Returns whether a finding is suppressed.
    fn suppress(&mut self, line: usize, scope: &str) -> bool {
        let mut n = line;
        loop {
            if let Some(slots) = self.allows.get_mut(&n) {
                if let Some(slot) =
                    slots.iter_mut().find(|s| s.0 == scope && !s.1)
                {
                    slot.1 = true;
                    return true;
                }
            }
            if n <= 1 || !self.comment_only.contains(&(n - 1)) {
                return false;
            }
            n -= 1;
        }
    }
}

fn finding(
    file: &str,
    line: usize,
    rule: &'static str,
    message: String,
) -> Finding {
    Finding { file: file.to_string(), line, rule, message }
}

/// The function name out of a trimmed `pub fn …` signature line.
fn fn_name(trimmed: &str) -> &str {
    let after = match trimmed.find("fn ") {
        Some(p) => &trimmed[p + 3..],
        None => trimmed,
    };
    match after.find(['(', '<', ' ']) {
        Some(p) => &after[..p],
        None => after,
    }
}

/// Collect the `{ … }` block opening at byte `bp` of line `bj` into one
/// string (code view), returning it plus the index of the closing line.
fn block_text(lines: &[Line], bj: usize, bp: usize) -> (String, usize) {
    let mut depth = 0i64;
    let mut body = String::new();
    let mut k = bj;
    while k < lines.len() {
        let code = &lines[k].code;
        let seg = if k == bj { &code[bp..] } else { code.as_str() };
        for (ci, ch) in seg.char_indices() {
            match ch {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        body.push_str(&seg[..ci]);
                        return (body, k);
                    }
                }
                _ => {}
            }
        }
        body.push_str(seg);
        body.push('\n');
        k += 1;
    }
    (body, lines.len().saturating_sub(1))
}

/// Rule 1 — **choke-point coverage**. Every non-test `pub fn` taking
/// `&mut self` must emit through `self.trace` *and* touch
/// index-maintenance state (see [`INDEX_TOKENS`]), or carry
/// `// pcm-lint: allow(untraced|unindexed) -- <reason>` above its
/// signature. Applied to `coordinator/scheduler.rs` and
/// `coordinator/sharded.rs`: a new mutation path can never ship
/// unobserved or unindexed.
pub fn check_choke_points(file: &str, source: &str) -> Vec<Finding> {
    let lines = scan(source);
    let mut sup = Suppressor::new(&lines);
    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        if lines[i].in_test {
            i += 1;
            continue;
        }
        let trimmed = lines[i].code.trim_start();
        let is_pub_fn = trimmed.starts_with("pub fn ")
            || trimmed.starts_with("pub(crate) fn ")
            || trimmed.starts_with("pub(super) fn ");
        if !is_pub_fn {
            i += 1;
            continue;
        }
        let sig_line = lines[i].number;
        let name = fn_name(trimmed).to_string();
        // Accumulate the signature up to the body-opening brace —
        // multi-line signatures put `&mut self` on a continuation line.
        let mut sig = String::new();
        let mut open = None;
        let mut j = i;
        while j < lines.len() {
            let code = &lines[j].code;
            if let Some(p) = code.find('{') {
                sig.push_str(&code[..p]);
                open = Some((j, p));
                break;
            }
            if code.contains(';') {
                break; // bodyless declaration
            }
            sig.push_str(code);
            sig.push(' ');
            j += 1;
        }
        let Some((bj, bp)) = open else {
            i = j + 1;
            continue;
        };
        let (body, end) = block_text(&lines, bj, bp);
        if sig.contains("&mut self") {
            if !body.contains("self.trace")
                && !sup.suppress(sig_line, "untraced")
            {
                out.push(finding(
                    file,
                    sig_line,
                    "choke-trace",
                    format!(
                        "pub fn {name}(&mut self, ..) mutates scheduler \
                         state without emitting through self.trace; \
                         trace it or annotate \
                         `// pcm-lint: allow(untraced) -- <reason>`"
                    ),
                ));
            }
            if !INDEX_TOKENS.iter().any(|t| body.contains(t))
                && !sup.suppress(sig_line, "unindexed")
            {
                out.push(finding(
                    file,
                    sig_line,
                    "choke-index",
                    format!(
                        "pub fn {name}(&mut self, ..) touches no \
                         index-maintenance state; update the indexes or \
                         annotate \
                         `// pcm-lint: allow(unindexed) -- <reason>`"
                    ),
                ));
            }
        }
        i = end + 1;
    }
    out
}

/// Rule 2 — **panic-free hot path**. No `unwrap()` / `expect(` /
/// `panic!` / `unreachable!` / `todo!` / `unimplemented!` in non-test
/// code without a reasoned `// pcm-lint: allow(panic)` annotation.
/// Applied to `coordinator/`, `live/`, `obs/`, and `cluster/`.
pub fn check_panics(file: &str, source: &str) -> Vec<Finding> {
    let lines = scan(source);
    let mut sup = Suppressor::new(&lines);
    let mut out = Vec::new();
    for l in &lines {
        if l.in_test {
            continue;
        }
        for tok in PANIC_TOKENS {
            for _ in 0..l.code.matches(tok).count() {
                if sup.suppress(l.number, "panic") {
                    continue;
                }
                out.push(finding(
                    file,
                    l.number,
                    "panic-free",
                    format!(
                        "`{tok}` on a hot path; convert to an error (or \
                         an infallible pattern), or annotate \
                         `// pcm-lint: allow(panic) -- <reason>`"
                    ),
                ));
            }
        }
    }
    out
}

/// Rule 3 — **no wildcard arms over `TraceEvent`**. A `_ =>` arm in a
/// match that handles `TraceEvent` variants silently swallows every
/// future variant, defeating compiler-enforced exhaustiveness as the
/// event vocabulary grows. Applied to `obs/`.
pub fn check_wildcard_trace_arms(file: &str, source: &str) -> Vec<Finding> {
    struct Frame {
        is_match: bool,
        trace_event: bool,
        wilds: Vec<usize>,
    }
    let lines = scan(source);
    let mut sup = Suppressor::new(&lines);
    let mut out = Vec::new();
    let mut stack: Vec<Frame> = Vec::new();
    let mut pending_match = false;
    for l in &lines {
        if l.in_test {
            // Test regions are brace-balanced, so skipping their lines
            // wholesale leaves the stack consistent.
            continue;
        }
        // Attribute arm-level facts to the innermost `match` frame as
        // of the start of the line (arms open their own blocks later
        // on the same line).
        if l.code.trim_start().starts_with("_ =>")
            || l.code.contains(", _ =>")
        {
            if let Some(f) = stack.iter_mut().rev().find(|f| f.is_match) {
                f.wilds.push(l.number);
            }
        }
        if l.code.contains("TraceEvent") {
            if let Some(f) = stack.iter_mut().rev().find(|f| f.is_match) {
                f.trace_event = true;
            }
        }
        let mut word = String::new();
        for ch in l.code.chars() {
            if ch.is_alphanumeric() || ch == '_' {
                word.push(ch);
                continue;
            }
            if word == "match" {
                pending_match = true;
            }
            word.clear();
            if ch == '{' {
                stack.push(Frame {
                    is_match: pending_match,
                    trace_event: false,
                    wilds: Vec::new(),
                });
                pending_match = false;
            } else if ch == '}' {
                if let Some(f) = stack.pop() {
                    if f.is_match && f.trace_event {
                        for w in f.wilds {
                            if sup.suppress(w, "wildcard") {
                                continue;
                            }
                            out.push(finding(
                                file,
                                w,
                                "trace-wildcard",
                                "wildcard `_ =>` arm in a match over \
                                 TraceEvent; list the variants (or \
                                 annotate `// pcm-lint: allow(wildcard) \
                                 -- <reason>`) so new events cannot be \
                                 silently ignored"
                                    .to_string(),
                            ));
                        }
                    }
                }
            }
        }
    }
    out
}

/// A lowercase field identifier (`[a-z_][a-z0-9_]*`) starting at byte
/// `at`, plus the byte index just past it.
fn field_ident(s: &str, at: usize) -> Option<(String, usize)> {
    let rest = s.get(at..)?;
    let mut name = String::new();
    for c in rest.chars() {
        if c.is_ascii_lowercase() || c == '_' {
            name.push(c);
        } else if c.is_ascii_digit() && !name.is_empty() {
            name.push(c);
        } else {
            break;
        }
    }
    (!name.is_empty()).then(|| (name.clone(), at + name.len()))
}

/// Record every `pat"name"` occurrence (closing quote required).
fn collect_after(
    raw: &str,
    pat: &str,
    line: usize,
    map: &mut BTreeMap<String, usize>,
) {
    let mut from = 0;
    while let Some(p) = raw[from..].find(pat) {
        let at = from + p + pat.len();
        if let Some((name, end)) = field_ident(raw, at) {
            if raw[end..].starts_with('"') {
                map.entry(name).or_insert(line);
            }
        }
        from += p + pat.len();
    }
}

/// Field names written by the serializers: `("name", …)` tuple heads
/// (skipping call/macro parens like `obj("…` or `format!("…`) and
/// `.insert("name"` map writes.
fn collect_emitted(
    raw: &str,
    line: usize,
    map: &mut BTreeMap<String, usize>,
) {
    let mut from = 0;
    while let Some(p) = raw[from..].find("(\"") {
        let p = from + p;
        let prev = raw[..p].trim_end().chars().last();
        let is_call = matches!(
            prev,
            Some(c) if c.is_alphanumeric() || c == '_' || c == '!'
        );
        if !is_call {
            if let Some((name, end)) = field_ident(raw, p + 2) {
                if raw[end..].starts_with("\",") {
                    map.entry(name).or_insert(line);
                }
            }
        }
        from = p + 2;
    }
    collect_after(raw, ".insert(\"", line, map);
}

/// Field names read back by the parser: `(j, "name")` helper calls,
/// `.req("name")`, and `.get("name")`.
fn collect_parsed(
    raw: &str,
    line: usize,
    map: &mut BTreeMap<String, usize>,
) {
    collect_after(raw, "(j, \"", line, map);
    collect_after(raw, ".req(\"", line, map);
    collect_after(raw, ".get(\"", line, map);
}

/// Rule 4 — **emit/parse field parity**. Every field name a serializer
/// writes must appear in the parser, and vice versa — one-sided JSONL
/// schema drift (a field added to `to_json` but not `from_json`, or a
/// parser key nothing ever writes) is caught at lint time. Applied to
/// `obs/event.rs`.
pub fn check_field_parity(file: &str, source: &str) -> Vec<Finding> {
    let lines = scan(source);
    let mut emitted: BTreeMap<String, usize> = BTreeMap::new();
    let mut parsed: BTreeMap<String, usize> = BTreeMap::new();
    for l in &lines {
        if l.in_test {
            continue;
        }
        collect_emitted(&l.raw, l.number, &mut emitted);
        collect_parsed(&l.raw, l.number, &mut parsed);
    }
    let mut out = Vec::new();
    for (name, line) in &emitted {
        if !parsed.contains_key(name) {
            out.push(finding(
                file,
                *line,
                "field-parity",
                format!(
                    "serialized field {name:?} is never read back by \
                     the parser (one-sided schema drift)"
                ),
            ));
        }
    }
    for (name, line) in &parsed {
        if !emitted.contains_key(name) {
            out.push(finding(
                file,
                *line,
                "field-parity",
                format!(
                    "parsed field {name:?} is never written by any \
                     serializer (one-sided schema drift)"
                ),
            ));
        }
    }
    out
}

/// Rule 5 — **atomic-ordering discipline**. `Ordering::Relaxed` is
/// permitted only on the documented stop-flag sites — recognized by
/// the word `stop` on the same line or the immediately preceding code
/// line — anything else needs `// pcm-lint: allow(relaxed) -- <reason>`
/// or a stronger ordering. Applied to `coordinator/`, `live/`, `obs/`,
/// and `cluster/`.
pub fn check_atomic_ordering(file: &str, source: &str) -> Vec<Finding> {
    let lines = scan(source);
    let mut sup = Suppressor::new(&lines);
    let mut out = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if l.in_test || !l.code.contains("Ordering::Relaxed") {
            continue;
        }
        let here = l.code.contains("stop");
        let before = lines[..i]
            .iter()
            .rev()
            .find(|p| !p.code.trim().is_empty())
            .is_some_and(|p| p.code.contains("stop"));
        if here || before || sup.suppress(l.number, "relaxed") {
            continue;
        }
        out.push(finding(
            file,
            l.number,
            "atomic-ordering",
            "Ordering::Relaxed outside a documented stop-flag site; \
             use a stronger ordering or annotate \
             `// pcm-lint: allow(relaxed) -- <reason>`"
                .to_string(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // ------------------------------------------------ rule 1: choke points

    const SCHED: &str = "coordinator/scheduler.rs";

    #[test]
    fn untraced_unindexed_mutation_fires_both_scopes() {
        let src = "impl Scheduler {\n\
                   \x20   pub fn sneak(&mut self, n: u64) {\n\
                   \x20       self.total += n;\n\
                   \x20   }\n\
                   }\n";
        let f = check_choke_points(SCHED, src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert_eq!(f[0].line, 2);
        assert_eq!(f[0].rule, "choke-trace");
        assert!(f[0].message.contains("sneak"));
        assert_eq!(f[1].line, 2);
        assert_eq!(f[1].rule, "choke-index");
        assert_eq!(f[0].file, SCHED);
    }

    #[test]
    fn traced_and_indexed_mutation_is_clean() {
        let src = "impl Scheduler {\n\
                   \x20   pub fn good(&mut self, id: u64) {\n\
                   \x20       self.idle.remove(&id);\n\
                   \x20       self.trace.emit(TraceEvent::WorkerLost);\n\
                   \x20   }\n\
                   }\n";
        assert!(check_choke_points(SCHED, src).is_empty());
    }

    #[test]
    fn multi_line_signature_is_accumulated() {
        // `&mut self` on the continuation line, like the real
        // `apply_decisions` / `phase_done`.
        let src = "impl Scheduler {\n\
                   \x20   pub fn long(\n\
                   \x20       &mut self,\n\
                   \x20       x: u64,\n\
                   \x20   ) -> bool {\n\
                   \x20       self.total = x;\n\
                   \x20       true\n\
                   \x20   }\n\
                   }\n";
        let f = check_choke_points(SCHED, src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert_eq!(f[0].line, 2, "finding anchors to the signature line");
    }

    #[test]
    fn shared_ref_and_owning_receivers_are_exempt() {
        let src = "impl Scheduler {\n\
                   \x20   pub fn read(&self) -> u64 { self.total }\n\
                   \x20   pub fn with_x(mut self) -> Self { self }\n\
                   }\n";
        assert!(check_choke_points(SCHED, src).is_empty());
    }

    #[test]
    fn allow_above_signature_suppresses_named_scopes_only() {
        let both = "impl Scheduler {\n\
                    \x20   // pcm-lint: allow(untraced|unindexed) -- fixture\n\
                    \x20   pub fn sneak(&mut self, n: u64) {\n\
                    \x20       self.total += n;\n\
                    \x20   }\n\
                    }\n";
        assert!(check_choke_points(SCHED, both).is_empty());
        let one = "impl Scheduler {\n\
                   \x20   // pcm-lint: allow(untraced) -- fixture\n\
                   \x20   pub fn sneak(&mut self, n: u64) {\n\
                   \x20       self.total += n;\n\
                   \x20   }\n\
                   }\n";
        let f = check_choke_points(SCHED, one);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "choke-index", "unindexed still fires");
    }

    #[test]
    fn allow_works_through_doc_comments() {
        let src = "impl Scheduler {\n\
                   \x20   // pcm-lint: allow(untraced|unindexed) -- fixture\n\
                   \x20   /// Doc comment between allow and signature.\n\
                   \x20   pub fn sneak(&mut self) {\n\
                   \x20       self.total += 1;\n\
                   \x20   }\n\
                   }\n";
        assert!(check_choke_points(SCHED, src).is_empty());
    }

    #[test]
    fn test_code_is_exempt_from_choke_points() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                   \x20   impl Scheduler {\n\
                   \x20       pub fn sneak(&mut self) { self.x += 1; }\n\
                   \x20   }\n\
                   }\n";
        assert!(check_choke_points(SCHED, src).is_empty());
    }

    // -------------------------------------------------- rule 2: panic-free

    #[test]
    fn each_panic_token_fires_with_file_and_line() {
        for tok in super::PANIC_TOKENS {
            let stmt = match *tok {
                ".unwrap()" => "x.unwrap()".to_string(),
                ".expect(" => "x.expect(\"why\")".to_string(),
                t => format!("{t}(\"boom\")"),
            };
            let src = format!("fn f() {{\n    {stmt};\n}}\n");
            let f = check_panics("live/driver.rs", &src);
            assert_eq!(f.len(), 1, "{tok} fires once: {f:?}");
            assert_eq!(f[0].line, 2, "{tok} anchors to its line");
            assert_eq!(f[0].file, "live/driver.rs");
            assert!(f[0].message.contains(tok), "{}", f[0].message);
        }
    }

    #[test]
    fn allow_suppresses_exactly_one_finding() {
        // Two panics on one line, one allow: one finding survives.
        let src = "fn f() {\n\
                   \x20   // pcm-lint: allow(panic) -- fixture reason\n\
                   \x20   a.unwrap() + b.unwrap();\n\
                   }\n";
        let f = check_panics("obs/sink.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn allow_without_reason_is_ignored() {
        let src = "fn f() {\n\
                   \x20   // pcm-lint: allow(panic)\n\
                   \x20   a.unwrap();\n\
                   }\n";
        assert_eq!(check_panics("obs/sink.rs", src).len(), 1);
    }

    #[test]
    fn allow_on_the_same_line_suppresses() {
        let src =
            "fn f() { a.unwrap() } // pcm-lint: allow(panic) -- fixture\n";
        assert!(check_panics("obs/sink.rs", src).is_empty());
    }

    #[test]
    fn panics_in_strings_comments_and_tests_are_exempt() {
        let src = "fn f() -> &'static str {\n\
                   \x20   // a comment mentioning .unwrap() and panic!\n\
                   \x20   \"literal .unwrap() panic! todo!\"\n\
                   }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   fn t() { None::<u32>.unwrap(); }\n\
                   }\n";
        assert!(check_panics("obs/sink.rs", src).is_empty());
    }

    #[test]
    fn infallible_lookalikes_do_not_fire() {
        let src = "fn f() {\n\
                   \x20   a.unwrap_or(0);\n\
                   \x20   b.unwrap_or_else(|| 1);\n\
                   \x20   c.unwrap_or_default();\n\
                   }\n";
        assert!(check_panics("live/driver.rs", src).is_empty());
    }

    // ------------------------------------------------ rule 3: no wildcards

    #[test]
    fn wildcard_over_trace_event_fires() {
        let src = "fn f(e: &TraceEvent) -> u32 {\n\
                   \x20   match e {\n\
                   \x20       TraceEvent::RunStart { .. } => 1,\n\
                   \x20       _ => 0,\n\
                   \x20   }\n\
                   }\n";
        let f = check_wildcard_trace_arms("obs/telemetry.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 4);
        assert_eq!(f[0].rule, "trace-wildcard");
    }

    #[test]
    fn wildcard_over_other_types_is_fine() {
        let src = "fn f(x: u32) -> u32 {\n\
                   \x20   match x {\n\
                   \x20       0 => 1,\n\
                   \x20       _ => 0,\n\
                   \x20   }\n\
                   }\n";
        assert!(check_wildcard_trace_arms("obs/mod.rs", src).is_empty());
    }

    #[test]
    fn nested_plain_match_inside_trace_match_is_fine() {
        let src = "fn f(e: &TraceEvent, x: u32) -> u32 {\n\
                   \x20   match e {\n\
                   \x20       TraceEvent::RunStart { .. } => match x {\n\
                   \x20           0 => 1,\n\
                   \x20           _ => 2,\n\
                   \x20       },\n\
                   \x20       TraceEvent::TaskDone { .. } => 3,\n\
                   \x20   }\n\
                   }\n";
        assert!(
            check_wildcard_trace_arms("obs/telemetry.rs", src).is_empty()
        );
    }

    #[test]
    fn wildcard_allow_suppresses() {
        let src = "fn f(e: &TraceEvent) -> u32 {\n\
                   \x20   match e {\n\
                   \x20       TraceEvent::RunStart { .. } => 1,\n\
                   \x20       // pcm-lint: allow(wildcard) -- fixture\n\
                   \x20       _ => 0,\n\
                   \x20   }\n\
                   }\n";
        assert!(
            check_wildcard_trace_arms("obs/telemetry.rs", src).is_empty()
        );
    }

    // ------------------------------------------------ rule 4: field parity

    #[test]
    fn emit_only_field_fires() {
        let src = "fn to_json() {\n\
                   \x20   let fields = vec![\n\
                   \x20       (\"task\", num_u(1)),\n\
                   \x20       (\"ghost\", num_u(2)),\n\
                   \x20   ];\n\
                   }\n\
                   fn from_json(j: &Json) {\n\
                   \x20   let _ = req_u64(j, \"task\");\n\
                   }\n";
        let f = check_field_parity("obs/event.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("ghost"));
        assert!(f[0].message.contains("never read back"));
    }

    #[test]
    fn parse_only_field_fires() {
        let src = "fn to_json() {\n\
                   \x20   let fields = vec![(\"task\", num_u(1))];\n\
                   }\n\
                   fn from_json(j: &Json) {\n\
                   \x20   let _ = req_u64(j, \"task\");\n\
                   \x20   let _ = j.get(\"phantom\");\n\
                   }\n";
        let f = check_field_parity("obs/event.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 6);
        assert!(f[0].message.contains("phantom"));
        assert!(f[0].message.contains("never written"));
    }

    #[test]
    fn balanced_fields_including_insert_and_get_are_clean() {
        let src = "fn to_json() {\n\
                   \x20   m.insert(\"event\".to_string(), v);\n\
                   \x20   fields.push((\"alt_worker\", num_u(9)));\n\
                   }\n\
                   fn from_json(j: &Json) {\n\
                   \x20   let _ = j.req(\"event\");\n\
                   \x20   let _ = j.get(\"alt_worker\");\n\
                   }\n";
        assert!(check_field_parity("obs/event.rs", src).is_empty());
    }

    #[test]
    fn macro_and_call_strings_are_not_fields() {
        // `bail!("…")` / `obj("…` are calls, not field tuples; prose
        // strings with spaces are not identifiers.
        let src = "fn to_json() {\n\
                   \x20   let fields = vec![(\"task\", num_u(1))];\n\
                   \x20   obj(\"task_done\", at, fields)\n\
                   }\n\
                   fn from_json(j: &Json) {\n\
                   \x20   let _ = req_u64(j, \"task\");\n\
                   \x20   bail!(\"unknown trace event kind\")\n\
                   }\n";
        assert!(check_field_parity("obs/event.rs", src).is_empty());
    }

    // -------------------------------------------- rule 5: atomic orderings

    #[test]
    fn relaxed_outside_stop_flag_fires() {
        let src = "fn f(done: &AtomicBool) {\n\
                   \x20   done.store(true, Ordering::Relaxed);\n\
                   }\n";
        let f = check_atomic_ordering("live/driver.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 2);
        assert_eq!(f[0].rule, "atomic-ordering");
    }

    #[test]
    fn stop_flag_sites_are_permitted() {
        let same = "fn f(s: &S) { s.stop.store(true, Ordering::Relaxed); }\n";
        assert!(check_atomic_ordering("live/worker.rs", same).is_empty());
        let prev = "fn f(pool: &Pool) {\n\
                    \x20   for flag in pool.stop_flags.values() {\n\
                    \x20       flag.store(true, Ordering::Relaxed);\n\
                    \x20   }\n\
                    }\n";
        assert!(check_atomic_ordering("live/driver.rs", prev).is_empty());
    }

    #[test]
    fn relaxed_allow_suppresses() {
        let src = "fn f(done: &AtomicBool) {\n\
                   \x20   // pcm-lint: allow(relaxed) -- fixture reason\n\
                   \x20   done.store(true, Ordering::Relaxed);\n\
                   }\n";
        assert!(check_atomic_ordering("live/driver.rs", src).is_empty());
    }

    #[test]
    fn cmp_ordering_is_not_atomics() {
        let src = "fn f(a: u32, b: u32) -> Ordering {\n\
                   \x20   a.cmp(&b)\n\
                   }\n";
        assert!(check_atomic_ordering("coordinator/scheduler.rs", src)
            .is_empty());
    }
}
