//! Threaded per-shard live runtime integration: the `pcm experiment
//! shards --threaded` path end to end, a hard kill landing on a
//! *lent* worker mid-task, and the error exits (watchdog trip,
//! drained pool) proving the shutdown ordering — every shard and
//! worker thread joined, no orphaned lent workers, cache root
//! removed.
//!
//! Everything runs offline on synthesized artifacts with the
//! deterministic reference backend, so these tests execute in CI —
//! including under ThreadSanitizer, where this binary is the
//! concurrency gate for the threaded runtime.

use pcm::cluster::{NodeAvailabilityTrace, NodeChurnEvent};
use pcm::coordinator::{ContextPolicy, PolicyKind};
use pcm::experiments::shards;
use pcm::live::{LiveApp, LiveConfig, LiveDriver};
use pcm::obs::TraceHandle;
use pcm::runtime::synthetic::{
    default_live_profiles, write_synthetic_artifacts,
};
use pcm::runtime::{BackendKind, Manifest};

fn synthetic_manifest(tag: &str) -> (std::path::PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!(
        "pcm-shards-threaded-test-{tag}-{}",
        std::process::id()
    ));
    write_synthetic_artifacts(&dir, &default_live_profiles())
        .expect("synthetic artifacts");
    let m = Manifest::load(&dir).expect("manifest loads");
    (dir, m)
}

/// A threaded two-shard live config over two "tiny" tenants. Tests in
/// this binary run in parallel threads of one process, and live cache
/// roots are keyed `pcm-live-{pid}-{seed}` — every test here must use
/// a distinct seed.
fn threaded_cfg(apps: Vec<LiveApp>, seed: u64) -> LiveConfig {
    LiveConfig {
        apps,
        shards: 2,
        threaded: true,
        steal: true,
        worker_speeds: vec![1.0, 1.0],
        policy: ContextPolicy::Pervasive,
        placement: PolicyKind::Greedy,
        backend: BackendKind::Reference,
        seed,
        ..LiveConfig::default()
    }
}

fn tiny_app(total_inferences: u64) -> LiveApp {
    LiveApp {
        profile: "tiny".into(),
        total_inferences,
        batch_size: 4,
    }
}

fn live_cache_root(seed: u64) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("pcm-live-{}-{seed}", std::process::id()))
}

/// The full `pcm experiment shards --threaded` path: the threaded
/// 2-shard run reproduces the serial 1-shard normalized event
/// multiset exactly, the unbalanced steal scenario lends a worker
/// across shard threads, every acceptance gate holds, and the report
/// renders its key lines. This is exactly what the
/// `shard-threaded-smoke` CI step runs through the CLI, and what the
/// tsan lane races.
#[test]
fn threaded_experiment_passes_its_gates() {
    let r = shards::run_threaded_shards(42, TraceHandle::null())
        .expect("threaded shards experiment runs");
    shards::verify_threaded(&r).expect("acceptance gates hold");

    assert_eq!(r.parity.only_in_threaded, 0, "trace parity");
    assert_eq!(r.parity.only_in_serial, 0, "trace parity");
    assert_eq!(r.parity.threaded.shards, 2);
    assert_eq!(r.parity.serial.shards, 1);
    assert!(r.steal.steals >= 1, "steal scenario lends a worker");

    let text = shards::report_threaded(&r);
    for needle in [
        "threaded live runtime equivalence",
        "parity_threaded2",
        "parity_serial1",
        "steal_threaded2",
        "only-threaded",
        "lends across shard threads",
    ] {
        assert!(text.contains(needle), "report missing {needle}:\n{text}");
    }
}

/// A hard kill that lands on a worker while it is *lent* to a peer
/// shard (the ISSUE-10 regression): the light shard drains its two
/// tasks (~0.3 s) and lends its worker to the backlogged heavy shard
/// well before the 0.9 s kill, so the reclaim hits a borrowed worker
/// mid-task on foreign ground. The coordinator must route the evict
/// to the *borrowing* shard's thread, requeue the in-flight batch
/// there, and drop the dead incarnation's late completions — nothing
/// lost, nothing double-scored, no double dispatch.
#[test]
fn hard_kill_of_lent_worker_requeues_without_loss() {
    let (dir, manifest) = synthetic_manifest("lendkill");
    let heavy: u64 = 64; // 16 tasks * 0.15 s floor ≈ 2.4 s of backlog
    let light: u64 = 8; // 2 tasks: the lender shard drains by ~0.35 s
    let mut cfg =
        threaded_cfg(vec![tiny_app(heavy), tiny_app(light)], 616_001);
    cfg.execute_floor_s = 0.15;
    cfg.node_trace = Some(NodeAvailabilityTrace::from_events(vec![
        NodeChurnEvent { time: 0.9, node: 1, up: false },
    ]));
    let out = LiveDriver::new(cfg, manifest).run().expect("run completes");
    let _ = std::fs::remove_dir_all(&dir);

    assert_eq!(out.completed_inferences, heavy + light, "no work lost");
    assert_eq!(out.shards, 2);
    assert!(out.steals >= 1, "the idle worker was lent before the kill");
    assert_eq!(out.evictions, 1, "exactly one kill");
    assert_eq!(out.restarts, 0, "node 1 never rejoins");
    assert!(out.warm_started.is_empty(), "nothing ever rejoined");
    // One completion record per task — a requeued batch re-runs under
    // its original task id (attempts grows), it never forks a second
    // record or a second score.
    assert_eq!(out.records.len() as u64, heavy / 4 + light / 4);
    if out.evicted_inferences > 0 {
        assert!(
            out.records.iter().any(|r| r.attempts >= 2),
            "an interrupted batch completes with attempts >= 2: {:?}",
            out.records.iter().map(|r| r.attempts).collect::<Vec<_>>()
        );
    }
    for (ctx, app) in &out.per_app {
        let want = if *ctx == 0 { heavy } else { light };
        assert_eq!(app.completed_inferences, want, "ctx {ctx}");
        assert_eq!(app.accuracy.total, want, "ctx {ctx} single-scored");
    }
}

/// Watchdog trip under the threaded runtime: the execute floor (1.5 s)
/// dwarfs the watchdog (0.35 s), so the run aborts mid-first-task.
/// The error exit must still walk the full shutdown ladder — stop
/// every worker mid-emulation-sleep, join every shard and worker
/// thread, and remove the run's cache root — before surfacing the
/// watchdog error.
#[test]
fn threaded_watchdog_error_exit_joins_and_cleans() {
    let (dir, manifest) = synthetic_manifest("watchdog");
    let seed = 616_002;
    let mut cfg = threaded_cfg(vec![tiny_app(8), tiny_app(8)], seed);
    cfg.execute_floor_s = 1.5;
    cfg.watchdog_s = 0.35;
    let t0 = std::time::Instant::now();
    let err = LiveDriver::new(cfg, manifest).run().expect_err("must stall");
    let elapsed = t0.elapsed();
    let _ = std::fs::remove_dir_all(&dir);

    let msg = err.to_string();
    assert!(msg.contains("watchdog"), "unexpected error: {msg}");
    assert!(
        !live_cache_root(seed).exists(),
        "error exit removes the cache root"
    );
    // Stop flags interrupt the 1.5 s emulation sleeps: the join-all
    // shutdown returns well before the floor would naturally elapse
    // twice over (generous bound for loaded CI runners).
    assert!(
        elapsed.as_secs_f64() < 10.0,
        "error exit hung for {elapsed:?}"
    );
}

/// Drained-pool bail under the threaded runtime: the trace kills both
/// nodes early with no scheduled rejoins, so the run can never finish.
/// The coordinator must detect the empty pool instead of idling until
/// the watchdog, and the error exit must leave no orphaned lent
/// workers and no cache root behind.
#[test]
fn threaded_drained_pool_error_exit_cleans() {
    let (dir, manifest) = synthetic_manifest("drained");
    let seed = 616_003;
    let mut cfg = threaded_cfg(vec![tiny_app(8), tiny_app(8)], seed);
    cfg.execute_floor_s = 0.5;
    cfg.node_trace = Some(NodeAvailabilityTrace::from_events(vec![
        NodeChurnEvent { time: 0.2, node: 0, up: false },
        NodeChurnEvent { time: 0.2, node: 1, up: false },
    ]));
    let err = LiveDriver::new(cfg, manifest).run().expect_err("must abort");
    let _ = std::fs::remove_dir_all(&dir);

    let msg = err.to_string();
    assert!(msg.contains("live pool drained"), "unexpected error: {msg}");
    assert!(
        !live_cache_root(seed).exists(),
        "error exit removes the cache root"
    );
}
