//! Live-mode integration: the same coordinator driving real PJRT
//! inference on worker threads. Requires `make artifacts` (skips when
//! absent). These tests are the proof that L1 (Pallas) + L2 (JAX HLO) +
//! L3 (Rust coordinator) compose with Python nowhere on the request path.

use pcm::coordinator::ContextPolicy;
use pcm::live::{LiveConfig, LiveDriver};
use pcm::runtime::manifest::default_artifacts_dir;
use pcm::runtime::Manifest;

fn manifest_or_skip() -> Option<Manifest> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest loads"))
}

fn cfg(policy: ContextPolicy, workers: usize, n: u64, batch: u64) -> LiveConfig {
    LiveConfig::builder()
        .app("tiny", n, batch)
        .policy(policy)
        .worker_speeds(vec![1.0; workers])
        .seed(3)
        .build()
        .expect("live test config is valid")
}

#[test]
fn live_pervasive_end_to_end() {
    let Some(m) = manifest_or_skip() else { return };
    let out = LiveDriver::new(cfg(ContextPolicy::Pervasive, 2, 64, 16), m)
        .run()
        .unwrap();
    assert_eq!(out.completed_inferences, 64);
    assert_eq!(out.accuracy.total, 64);
    assert!(out.throughput_inf_per_s > 0.0);
    assert_eq!(out.records.len(), 4);
    // At least one task per worker reused a warm context: its context
    // time is ~0.
    let warm = out.records.iter().filter(|r| r.context_s < 0.01).count();
    assert!(warm >= 1, "expected warm-context tasks, records: {:?}",
        out.records.iter().map(|r| r.context_s).collect::<Vec<_>>());
}

#[test]
fn live_pervasive_amortizes_context_costs() {
    let Some(m) = manifest_or_skip() else { return };
    // 6 tasks on 1 worker: pervasive pays context once, partial 6 times.
    let perv = LiveDriver::new(cfg(ContextPolicy::Pervasive, 1, 48, 8), m)
        .run()
        .unwrap();
    let m2 = manifest_or_skip().unwrap();
    let part = LiveDriver::new(cfg(ContextPolicy::Partial, 1, 48, 8), m2)
        .run()
        .unwrap();
    let perv_ctx: f64 = perv.records.iter().map(|r| r.context_s).sum();
    let part_ctx: f64 = part.records.iter().map(|r| r.context_s).sum();
    assert!(
        part_ctx > 2.0 * perv_ctx,
        "partial total context {part_ctx:.3}s must dwarf pervasive {perv_ctx:.3}s"
    );
    // Both deliver identical verdict counts.
    assert_eq!(perv.completed_inferences, part.completed_inferences);
}

#[test]
fn live_accuracy_is_deterministic_across_policies() {
    // Same workload, same model → identical accuracy regardless of the
    // context-management policy (it only changes *when* work happens).
    let Some(m) = manifest_or_skip() else { return };
    let a = LiveDriver::new(cfg(ContextPolicy::Pervasive, 2, 32, 8), m)
        .run()
        .unwrap();
    let m2 = manifest_or_skip().unwrap();
    let b = LiveDriver::new(cfg(ContextPolicy::None, 1, 32, 8), m2)
        .run()
        .unwrap();
    assert_eq!(a.accuracy.correct, b.accuracy.correct);
    assert_eq!(a.accuracy.confusion, b.accuracy.confusion);
}

#[test]
fn live_heterogeneous_workers_complete() {
    let Some(m) = manifest_or_skip() else { return };
    let mut c = cfg(ContextPolicy::Pervasive, 2, 48, 8);
    c.worker_speeds = vec![1.0, 0.4]; // one emulated slow GPU
    let out = LiveDriver::new(c, m).run().unwrap();
    assert_eq!(out.completed_inferences, 48);
    // The fast worker should complete more tasks than the slow one.
    let mut per_worker = std::collections::HashMap::new();
    for r in &out.records {
        *per_worker.entry(r.worker).or_insert(0u32) += 1;
    }
    assert_eq!(per_worker.values().sum::<u32>(), 6);
}

#[test]
fn live_latency_stats_populated() {
    let Some(m) = manifest_or_skip() else { return };
    let out = LiveDriver::new(cfg(ContextPolicy::Pervasive, 2, 32, 8), m)
        .run()
        .unwrap();
    assert_eq!(out.task_latency.count(), 4);
    assert!(out.task_latency.max() >= out.task_latency.percentile(50.0));
    assert!(out.task_latency.min() > 0.0);
}
