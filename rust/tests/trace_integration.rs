//! End-to-end trace integration: record real experiment runs through a
//! [`JsonlSink`]/[`MemorySink`], replay them through the invariant
//! checker and the `pcm trace` CLI, and hold the trace-derived
//! telemetry to the driver's own outcome counters.
//!
//! Everything here runs offline (synthetic artifacts, reference
//! backend, sim engine) — these tests execute in CI.

use std::process::Command;
use std::sync::{Arc, Mutex};

use pcm::experiments::{churn, live_churn};
use pcm::obs::{
    check_events, read_trace, split_runs, JsonlSink, MemorySink, Telemetry,
    TraceEvent, TraceHandle,
};

fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("pcm-trace-it-{tag}-{}.jsonl", std::process::id()))
}

/// Record the sim churn experiment (reduced workload) to a JSONL file,
/// assert the recorded trace passes every scheduler invariant — both
/// in-process and through `pcm trace check` — then corrupt it by
/// duplicating a `task_done` line and assert the checker fails loudly.
#[test]
fn churn_trace_records_checks_and_catches_corruption() {
    let path = temp_path("churn");
    let trace =
        TraceHandle::new(JsonlSink::create(&path).expect("trace file"));
    let r = churn::run_churn(42, 1_000, 5_000, trace.clone());
    trace.flush();
    assert!(!r.bytes.is_empty(), "churn scenarios ran");

    let events = read_trace(&path).expect("trace parses back");
    assert!(
        events.len() > 100,
        "a three-scenario churn run leaves a substantial trace, got {}",
        events.len()
    );
    // One run_start per scenario: two bytes-axis runs + the warm run.
    let runs = split_runs(&events);
    assert_eq!(runs.len(), 3, "one segment per scenario");
    // Churn scenarios must actually churn, and the trace must show it.
    let t = Telemetry::from_events(runs[0]);
    assert!(t.node_reclaims > 0, "reclamation storm traced");
    assert!(t.worker_losses > 0, "evictions traced");
    assert!(t.completed > 0 && t.completed_inferences > 0);

    let violations = check_events(&events);
    assert!(violations.is_empty(), "clean run violates nothing: {violations:?}");

    // The CLI agrees: `pcm trace check` exits 0 on the clean trace.
    let ok = Command::new(env!("CARGO_BIN_EXE_pcm"))
        .args(["trace", "check", path.to_str().unwrap()])
        .output()
        .expect("pcm trace check runs");
    assert!(
        ok.status.success(),
        "clean trace passes: {}",
        String::from_utf8_lossy(&ok.stderr)
    );
    // `pcm trace summarize` renders every segment.
    let sum = Command::new(env!("CARGO_BIN_EXE_pcm"))
        .args(["trace", "summarize", path.to_str().unwrap()])
        .output()
        .expect("pcm trace summarize runs");
    assert!(sum.status.success());
    let text = String::from_utf8_lossy(&sum.stdout);
    assert_eq!(
        text.matches("run label=").count(),
        3,
        "summarize shows all three segments:\n{text}"
    );

    // Corrupt: replay the LAST task_done a second time (a double-scored
    // task). The checker must refuse, and the CLI must exit non-zero.
    let raw = std::fs::read_to_string(&path).expect("raw trace");
    let dup = raw
        .lines()
        .rev()
        .find(|l| l.contains("\"task_done\""))
        .expect("trace contains task_done lines")
        .to_string();
    std::fs::write(&path, format!("{raw}{dup}\n")).expect("corrupt trace");
    let corrupted = read_trace(&path).expect("still parseable");
    let violations = check_events(&corrupted);
    assert!(
        violations.iter().any(|v| v.message.contains("completed twice")),
        "duplicate task_done is flagged: {violations:?}"
    );
    let bad = Command::new(env!("CARGO_BIN_EXE_pcm"))
        .args(["trace", "check", path.to_str().unwrap()])
        .output()
        .expect("pcm trace check runs");
    assert!(!bad.status.success(), "corrupted trace must fail the CLI");
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("violation"),
        "failure lists the violations"
    );
    let _ = std::fs::remove_file(&path);
}

/// The live acceptance tie: warm-restored bytes reconstructed from the
/// trace alone must equal the live driver's own `warm_started` outcome
/// exactly — worker for worker, byte for byte.
#[test]
fn live_trace_warm_restores_match_outcome_exactly() {
    let sink = Arc::new(Mutex::new(MemorySink::unbounded()));
    let r = live_churn::run_live_churn(
        42,
        TraceHandle::from_shared(sink.clone()),
    )
    .expect("live churn runs");
    let events = sink.lock().unwrap().events();
    assert!(
        events.iter().any(|e| matches!(e, TraceEvent::RunStart { .. })),
        "live runs announce themselves"
    );
    let violations = check_events(&events);
    assert!(violations.is_empty(), "live trace is clean: {violations:?}");

    // Only the restart scenario warm-restores, so folding the whole
    // two-scenario stream still yields exactly its warm_started map.
    let t = Telemetry::from_events(&events);
    assert!(!r.restart.warm_started.is_empty(), "a restore happened");
    assert_eq!(
        t.restored_bytes_by_worker, r.restart.warm_started,
        "trace-derived warm-restored bytes match the live outcome"
    );
    let rendered = t.render();
    for (wid, bytes) in &r.restart.warm_started {
        assert!(
            rendered.contains(&format!("worker={wid} bytes={bytes}")),
            "summary reports the restore:\n{rendered}"
        );
    }
    // The kill/restart itself is visible in the stream.
    assert!(events.iter().any(|e| matches!(e, TraceEvent::WorkerLost { .. })));
    assert!(events.iter().any(|e| matches!(e, TraceEvent::CacheRestore { .. })));
}

// ---------------------------------------------------------------------
// Each `obs::check` Violation class individually, from hand-assembled
// event streams (the end-to-end tests above only ever see clean runs
// plus the duplicated-completion corruption).

fn run_start() -> TraceEvent {
    TraceEvent::RunStart {
        at: 0.0,
        label: "hand-assembled".into(),
        policy: "greedy".into(),
    }
}

fn join(worker: u64, capacity: u64) -> TraceEvent {
    TraceEvent::WorkerJoin { at: 0.0, worker, node: worker, capacity, shard: None }
}

fn stage(worker: u64, ctx: u32, bytes: u64, version: u32) -> TraceEvent {
    TraceEvent::CacheStage {
        at: 1.0,
        worker,
        ctx,
        component: "weights".into(),
        bytes,
        version,
    }
}

/// Staging more bytes onto a worker than its announced capacity is the
/// occupancy invariant the byte-budgeted caches exist to hold.
#[test]
fn checker_flags_over_capacity_occupancy() {
    let events = vec![
        run_start(),
        join(0, 100),
        stage(0, 0, 80, 0),
        stage(0, 1, 30, 0), // 110 > 100
    ];
    let violations = check_events(&events);
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert!(
        violations[0].message.contains("exceeds capacity"),
        "{}",
        violations[0].message
    );
    // The index points at the offending stage event.
    assert_eq!(violations[0].index, 3);

    // At exactly capacity there is nothing to report.
    let exact = vec![run_start(), join(0, 100), stage(0, 0, 100, 0)];
    assert!(check_events(&exact).is_empty());
}

/// Cache traffic attributed to a worker that never joined (or was
/// already lost) means the trace lost a lifecycle event — every byte
/// must be attributable to a live incarnation.
#[test]
fn checker_flags_traffic_for_never_joined_worker() {
    let events = vec![run_start(), stage(7, 0, 10, 0)];
    let violations = check_events(&events);
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert!(
        violations[0].message.contains("never joined"),
        "{}",
        violations[0].message
    );

    // Same story after an explicit loss.
    let lost = vec![
        run_start(),
        join(0, 100),
        TraceEvent::WorkerLost { at: 0.5, worker: 0, node: 0 },
        stage(0, 0, 10, 0),
    ];
    let violations = check_events(&lost);
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert!(violations[0].message.contains("never joined (or was lost)"));
}

/// Bytes staged under a version older than the registry's current one
/// are stale-version bytes — the invariant behind every version bump
/// and warm-restore drop.
#[test]
fn checker_flags_stale_version_bytes() {
    let events = vec![
        run_start(),
        join(0, 1_000),
        TraceEvent::VersionBump { at: 0.5, ctx: 0, version: 1 },
        stage(0, 0, 10, 0), // staged at version 0, registry at 1
    ];
    let violations = check_events(&events);
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert!(
        violations[0].message.contains("stale bytes served"),
        "{}",
        violations[0].message
    );

    // Staging at the bumped version is clean.
    let fresh = vec![
        run_start(),
        join(0, 1_000),
        TraceEvent::VersionBump { at: 0.5, ctx: 0, version: 1 },
        stage(0, 0, 10, 1),
    ];
    assert!(check_events(&fresh).is_empty());
}
