//! Live kill/restart integration: the live driver hosting multiple
//! applications, losing a worker mid-run to a wall-clock availability
//! trace, and warm-starting its replacement from the surviving
//! node-keyed cache directory.
//!
//! Unlike the PJRT-gated tests in `live_integration.rs`, everything
//! here runs offline: artifacts are synthesized
//! (`runtime::synthetic`) and workers use the deterministic reference
//! backend — so these tests execute in CI, not just on
//! artifact-equipped checkouts.

use pcm::cluster::{NodeAvailabilityTrace, NodeChurnEvent};
use pcm::coordinator::ContextPolicy;
use pcm::experiments::live_churn;
use pcm::live::{LiveApp, LiveConfig, LiveDriver};
use pcm::obs::TraceHandle;
use pcm::runtime::synthetic::{
    default_live_profiles, write_synthetic_artifacts,
};
use pcm::runtime::{BackendKind, Manifest};

fn synthetic_manifest(tag: &str) -> (std::path::PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!(
        "pcm-live-churn-test-{tag}-{}",
        std::process::id()
    ));
    write_synthetic_artifacts(&dir, &default_live_profiles())
        .expect("synthetic artifacts");
    let m = Manifest::load(&dir).expect("manifest loads");
    (dir, m)
}

/// The full `pcm experiment live-churn` path: both scenarios complete,
/// every acceptance gate holds, and the report renders its key lines.
/// This is exactly what the `live-smoke` CI job runs through the CLI.
#[test]
fn live_churn_experiment_passes_its_gates() {
    let r = live_churn::run_live_churn(42, TraceHandle::null())
        .expect("live churn runs");
    live_churn::verify(&r).expect("acceptance gates hold");

    // (a) No inference lost or double-scored across the kill: every
    // app's scheduler count and scored count equal its workload.
    for (ctx, app) in &r.restart.per_app {
        assert_eq!(
            app.completed_inferences,
            live_churn::RESTART_INFERENCES_PER_APP,
            "ctx {ctx} completed"
        );
        assert_eq!(
            app.accuracy.total,
            live_churn::RESTART_INFERENCES_PER_APP,
            "ctx {ctx} scored exactly once per inference"
        );
    }
    // (b) The restarted worker warm-started with real bytes.
    assert!(!r.restart.warm_started.is_empty());
    assert!(r.restart.warm_started.values().all(|&b| b > 0));
    // Restarted worker ids are fresh incarnations (never reused).
    for wid in r.restart.warm_started.keys() {
        assert!(*wid >= 1, "incarnation ids grow monotonically");
    }
    // (c) Under the shrunken cache, evictions hit the larger context
    // only.
    assert!(r.contention.cache.ctx(r.larger_ctx).evictions >= 1);
    assert_eq!(r.contention.cache.ctx(r.smaller_ctx).evictions, 0);

    let text = live_churn::report(&r);
    for needle in [
        "live restart scenario",
        "warm_started_workers=1",
        "first-task context seconds",
        "live contention scenario",
        "larger",
    ] {
        assert!(text.contains(needle), "report missing {needle}:\n{text}");
    }
}

/// A hard kill that is *guaranteed* to land mid-task (the execute floor
/// makes the first task outlive the kill time): the in-flight batch is
/// requeued through the ordinary retry machinery onto the surviving
/// worker, nothing is lost, nothing is double-scored, and the dead
/// incarnation's late messages are discarded.
#[test]
fn hard_kill_mid_task_requeues_without_loss() {
    let (dir, manifest) = synthetic_manifest("hardkill");
    let per_app: u64 = 24;
    let cfg = LiveConfig {
        policy: ContextPolicy::Pervasive,
        apps: vec![
            LiveApp {
                profile: "tiny".into(),
                total_inferences: per_app,
                batch_size: 8,
            },
            LiveApp {
                profile: "small".into(),
                total_inferences: per_app,
                batch_size: 8,
            },
        ],
        worker_speeds: vec![1.0, 1.0],
        seed: 7,
        backend: BackendKind::Reference,
        // First TaskDone cannot arrive before the 0.25 s execute floor,
        // so a kill at 0.12 s always interrupts an in-flight task.
        execute_floor_s: 0.25,
        node_trace: Some(NodeAvailabilityTrace::from_events(vec![
            NodeChurnEvent { time: 0.12, node: 0, up: false },
        ])),
        ..LiveConfig::default()
    };
    let out = LiveDriver::new(cfg, manifest).run().expect("run completes");
    let _ = std::fs::remove_dir_all(&dir);

    assert_eq!(out.completed_inferences, 2 * per_app, "no work lost");
    assert_eq!(out.evictions, 1, "exactly one kill");
    assert_eq!(out.restarts, 0, "node 0 never rejoins");
    assert!(
        out.evicted_inferences > 0,
        "the kill must have interrupted an in-flight batch"
    );
    // The interrupted batch re-ran: its completion record counts both
    // attempts, and each app still scored exactly its workload.
    assert!(
        out.records.iter().any(|r| r.attempts >= 2),
        "requeued task completes with attempts >= 2: {:?}",
        out.records.iter().map(|r| r.attempts).collect::<Vec<_>>()
    );
    for (ctx, app) in &out.per_app {
        assert_eq!(app.completed_inferences, per_app, "ctx {ctx}");
        assert_eq!(app.accuracy.total, per_app, "ctx {ctx} single-scored");
    }
    // Every surviving completion ran on the surviving worker or before
    // the kill on worker 0 — never on a dead incarnation after its kill.
    assert!(out.warm_started.is_empty(), "nothing ever rejoined");
}

/// `keep_cache_root` (the `PCM_KEEP_LIVE_CACHE` config twin) leaves the
/// run's node-keyed cache dirs on disk for inspection — including the
/// per-context subdirectories a future incarnation would warm-start
/// from.
#[test]
fn keep_cache_root_preserves_node_dirs() {
    let (dir, manifest) = synthetic_manifest("keeproot");
    let seed = 777_001;
    let cfg = LiveConfig {
        policy: ContextPolicy::Pervasive,
        apps: vec![LiveApp {
            profile: "tiny".into(),
            total_inferences: 16,
            batch_size: 8,
        }],
        worker_speeds: vec![1.0],
        seed,
        backend: BackendKind::Reference,
        persist_node_caches: true,
        keep_cache_root: true,
        ..LiveConfig::default()
    };
    let out = LiveDriver::new(cfg, manifest).run().expect("run completes");
    assert_eq!(out.completed_inferences, 16);
    let root = std::env::temp_dir()
        .join(format!("pcm-live-{}-{seed}", std::process::id()));
    assert!(root.exists(), "cache root kept at {}", root.display());
    let ctx_dir = root.join("node-0").join("ctx-0");
    assert!(
        ctx_dir.join("weights.bin").exists(),
        "staged weights survive under the node-keyed per-context dir"
    );
    let _ = std::fs::remove_dir_all(&root);
    let _ = std::fs::remove_dir_all(&dir);
}
