//! Multi-application integration: two PfF apps with distinct contexts
//! (7.4 GB vs 15 GB) sharing one opportunistic 20-node pool, with worker
//! caches too small to hold both contexts at once. End-to-end through
//! the simulated driver: completion, policy ordering, per-context cache
//! accounting, affinity behaviour, and determinism.

use pcm::cluster::LoadTrace;
use pcm::coordinator::{ContextPolicy, SimDriver};
use pcm::experiments::mixed::{self, MixedResult};

const SEED: u64 = 42;
const PER_APP: u64 = 1_000;

fn by_policy(results: &[MixedResult], p: ContextPolicy) -> &MixedResult {
    results.iter().find(|r| r.policy == p).expect("policy present")
}

#[test]
fn mixed_run_completes_both_apps_under_all_policies() {
    let results = mixed::run_mixed(SEED, PER_APP);
    assert_eq!(results.len(), 3);
    for r in &results {
        assert_eq!(
            r.outcome.summary.completed_inferences,
            2 * PER_APP,
            "{} must finish both apps",
            r.id
        );
        assert_eq!(r.completed_for(0), PER_APP, "{} app A complete", r.id);
        assert_eq!(r.completed_for(1), PER_APP, "{} app B complete", r.id);
    }
}

#[test]
fn mixed_pervasive_beats_none_by_at_least_5x() {
    let results = mixed::run_mixed(SEED, PER_APP);
    let none = by_policy(&results, ContextPolicy::None)
        .outcome
        .summary
        .exec_time_s;
    let perv = by_policy(&results, ContextPolicy::Pervasive)
        .outcome
        .summary
        .exec_time_s;
    assert!(
        perv * 5.0 <= none,
        "pervasive {perv:.1}s must beat none {none:.1}s by >= 5x \
         (ratio {:.2})",
        none / perv
    );
    // And partial sits in between.
    let part = by_policy(&results, ContextPolicy::Partial)
        .outcome
        .summary
        .exec_time_s;
    assert!(perv < part && part < none, "pv4 < pv2 < pv1 ordering");
}

#[test]
fn mixed_reports_per_context_cache_counters() {
    let results = mixed::run_mixed(SEED, PER_APP);
    for r in &results {
        // Both contexts staged something at least once.
        assert!(r.outcome.cache.ctx(0).misses > 0, "{} ctx0 misses", r.id);
        assert!(r.outcome.cache.ctx(1).misses > 0, "{} ctx1 misses", r.id);
    }
    // Under Pervasive the warm fast path produces hits for both tenants.
    let perv = by_policy(&results, ContextPolicy::Pervasive);
    assert!(perv.outcome.cache.ctx(0).hits > 0, "pv4 ctx0 hits");
    assert!(perv.outcome.cache.ctx(1).hits > 0, "pv4 ctx1 hits");
    // The None policy never caches, so it can never hit.
    let none = by_policy(&results, ContextPolicy::None);
    assert_eq!(none.outcome.cache.totals().hits, 0, "pv1 cannot hit");
    assert_eq!(none.outcome.cache.totals().evictions, 0);
    // The report renders every policy row and both context lines.
    let text = mixed::report(&results);
    for needle in ["mixed_pv1", "mixed_pv2", "mixed_pv4", "ctx=0", "ctx=1"] {
        assert!(text.contains(needle), "report missing {needle}");
    }
}

#[test]
fn unbalanced_apps_force_context_eviction_under_cache_pressure() {
    // 2 workers, app A much smaller than app B: when A drains, its warm
    // worker must flip to B — and with 16 GB caches that flip cannot
    // happen without LRU-evicting A's 7.4 GB context.
    let mut cfg = mixed::mixed_config(
        "mixed_flip",
        ContextPolicy::Pervasive,
        7,
        1_000,
    );
    cfg.nodes.truncate(2);
    cfg.trace = LoadTrace::constant(2);
    cfg.apps[0].total_inferences = 200;
    cfg.apps[1].total_inferences = 1_000;
    let out = SimDriver::new(cfg).run();
    assert_eq!(out.summary.completed_inferences, 1_200);
    assert!(
        out.cache.ctx(0).evictions > 0,
        "draining app A must get LRU-evicted when its worker flips to B \
         (stats: {:?})",
        out.cache.per_context
    );
}

#[test]
fn mixed_runs_are_deterministic_per_seed() {
    let a = mixed::run_mixed(9, 500);
    let b = mixed::run_mixed(9, 500);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.outcome.summary.exec_time_s, y.outcome.summary.exec_time_s);
        assert_eq!(
            x.outcome.cache.per_context, y.outcome.cache.per_context,
            "{} cache stats must be reproducible",
            x.id
        );
    }
}
