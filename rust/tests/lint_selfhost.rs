//! The lint's primary integration test: `pcm lint` must pass on its
//! own tree (self-hosting), and must catch a deliberately planted
//! violation in a fixture crate with a file/line diagnostic.
//!
//! Everything here runs offline — these tests execute in the
//! `static-analysis`-adjacent CI test lane.

use std::path::{Path, PathBuf};
use std::process::Command;

/// In-process self-host: linting this very crate yields zero findings.
/// Every suppression in the tree therefore carries a reason, and every
/// choke-point method traces and indexes (or is explicitly exempted).
#[test]
fn lint_crate_self_hosts_clean() {
    let findings = pcm::lint::lint_crate(Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("lint walks its own sources");
    assert!(
        findings.is_empty(),
        "the tree must self-host clean; findings:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// The CLI agrees with the library: `pcm lint --manifest-dir <crate>`
/// exits 0 and announces the clean tree on stdout.
#[test]
fn cli_lint_passes_on_own_tree() {
    let out = Command::new(env!("CARGO_BIN_EXE_pcm"))
        .args(["lint", "--manifest-dir", env!("CARGO_MANIFEST_DIR")])
        .output()
        .expect("pcm lint runs");
    assert!(
        out.status.success(),
        "self-hosting lint exits 0; stderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("pcm lint: OK"),
        "clean run is announced: {}",
        String::from_utf8_lossy(&out.stdout)
    );
}

/// Fixture crate dir holding exactly one source file at `rel`.
fn fixture_crate(tag: &str, rel: &str, source: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("pcm-lint-it-{tag}-{}", std::process::id()));
    let file = dir.join("src").join(rel);
    std::fs::create_dir_all(file.parent().expect("rel has a parent"))
        .expect("fixture dirs");
    std::fs::write(&file, source).expect("fixture source");
    dir
}

/// The acceptance fixture: a scheduler source with a deliberately
/// untraced, unindexed `pub fn (&mut self)` mutator. The CLI must exit
/// non-zero and point at the exact file and line of the offender.
#[test]
fn cli_lint_catches_untraced_scheduler_method() {
    let src = "pub struct Scheduler {\n\
               \x20   total: u64,\n\
               }\n\
               \n\
               impl Scheduler {\n\
               \x20   pub fn sneak(&mut self, n: u64) {\n\
               \x20       self.total += n;\n\
               \x20   }\n\
               }\n";
    let dir = fixture_crate("sneak", "coordinator/scheduler.rs", src);
    let out = Command::new(env!("CARGO_BIN_EXE_pcm"))
        .args(["lint", "--manifest-dir", dir.to_str().expect("utf-8 tmp")])
        .output()
        .expect("pcm lint runs");
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(
        !out.status.success(),
        "planted violation must fail the CLI; stderr:\n{stderr}"
    );
    // `pub fn sneak` sits on line 6 of the fixture: both choke rules
    // anchor their diagnostics there.
    assert!(
        stderr.contains("coordinator/scheduler.rs:6"),
        "diagnostic names the file and line:\n{stderr}"
    );
    assert!(stderr.contains("[choke-trace]"), "untraced is flagged:\n{stderr}");
    assert!(stderr.contains("[choke-index]"), "unindexed is flagged:\n{stderr}");
    assert!(
        stderr.contains("allow(untraced)"),
        "diagnostic teaches the suppression syntax:\n{stderr}"
    );
    assert!(
        stderr.contains("pcm lint: 2 finding(s)"),
        "summary counts both findings:\n{stderr}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A reasoned allow on the planted method suppresses exactly the named
/// scopes and restores a clean exit — the suppression path works
/// end-to-end through the CLI, not just in rule unit tests.
#[test]
fn cli_lint_accepts_reasoned_allow_on_fixture() {
    let src = "pub struct Scheduler {\n\
               \x20   total: u64,\n\
               }\n\
               \n\
               impl Scheduler {\n\
               \x20   // pcm-lint: allow(untraced|unindexed) -- fixture:\n\
               \x20   // plain counter bump, no queue state involved.\n\
               \x20   pub fn sneak(&mut self, n: u64) {\n\
               \x20       self.total += n;\n\
               \x20   }\n\
               }\n";
    let dir = fixture_crate("allowed", "coordinator/scheduler.rs", src);
    let out = Command::new(env!("CARGO_BIN_EXE_pcm"))
        .args(["lint", "--manifest-dir", dir.to_str().expect("utf-8 tmp")])
        .output()
        .expect("pcm lint runs");
    assert!(
        out.status.success(),
        "reasoned allow restores a clean exit; stderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Hot-path panic tokens outside the scheduler are caught too, with the
/// panic-free rule naming the offending token and line.
#[test]
fn cli_lint_catches_hot_path_unwrap() {
    let src = "pub fn helper(x: Option<u64>) -> u64 {\n\
               \x20   x.unwrap()\n\
               }\n";
    let dir = fixture_crate("unwrap", "live/driver.rs", src);
    let out = Command::new(env!("CARGO_BIN_EXE_pcm"))
        .args(["lint", "--manifest-dir", dir.to_str().expect("utf-8 tmp")])
        .output()
        .expect("pcm lint runs");
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(!out.status.success(), "unwrap on a hot path fails the CLI");
    assert!(
        stderr.contains("live/driver.rs:2") && stderr.contains("[panic-free]"),
        "diagnostic names file, line, and rule:\n{stderr}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
