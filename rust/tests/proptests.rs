//! Property-based tests over coordinator invariants.
//!
//! The vendored offline crate set has no `proptest`, so this file uses an
//! in-tree property harness: each property runs against a few hundred
//! randomized cases drawn from the deterministic SplitMix64 RNG, with the
//! failing seed printed on panic — same methodology, zero dependencies.

use pcm::cluster::node::pool_20_mixed;
use pcm::cluster::{ClusterAction, ClusterSim, GpuModel, LoadTrace, Node};
use pcm::coordinator::batcher::Batcher;
use pcm::coordinator::policy::{
    PlacementPolicy, SchedulerView, WeightedFairShare,
};
use pcm::coordinator::scheduler::PhaseKind;
use pcm::coordinator::transfer::{broadcast_rounds, plan_broadcast};
use pcm::coordinator::{
    ComponentKind, ContextPolicy, ContextRecipe, CostModel, Scheduler, Task,
    TaskRecord, TransferPlanner, Worker,
};
use pcm::util::Rng;

/// Run `prop` for `cases` seeds; panic messages carry the seed.
fn forall(cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9) ^ 0xABCD);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut rng)),
        );
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

// ---------------------------------------------------------------- batcher

#[test]
fn prop_batcher_partition_is_exact_cover() {
    forall(300, |rng| {
        let total = 1 + rng.below(50_000) as u64;
        let batch = 1 + rng.below(9_000) as u64;
        let tasks = Batcher::new(batch).split(total, 0, 0);
        // Covers [0, total) exactly, in order, without gaps or overlap.
        let mut expect = 0u64;
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(t.id, i as u64);
            assert_eq!(t.start, expect);
            assert!(t.count >= 1 && t.count <= batch);
            expect += t.count;
        }
        assert_eq!(expect, total);
        // All but the last task are full-size.
        for t in &tasks[..tasks.len() - 1] {
            assert_eq!(t.count, batch);
        }
    });
}

// ------------------------------------------------------------ broadcast

#[test]
fn prop_broadcast_tree_valid() {
    forall(300, |rng| {
        let n = rng.below(300);
        let cap = 1 + rng.below(6) as u32;
        let ids: Vec<u32> = (0..n as u32).collect();
        let edges = plan_broadcast(&ids, cap);
        assert_eq!(edges.len(), n);
        // Every worker covered exactly once; parents must already hold
        // the data (appear as an earlier child or be the seed).
        let mut seen = std::collections::HashSet::new();
        for e in &edges {
            if let Some(p) = e.parent {
                assert!(seen.contains(&p), "parent {p} before child");
            }
            assert!(seen.insert(e.child));
        }
        // Rounds are logarithmic: holders multiply by (cap+1) per round.
        if n > 0 {
            let rounds = broadcast_rounds(n, cap);
            let mut holders = 1u64;
            let mut needed = 1u32;
            while (holders as usize) < n {
                holders += holders * cap as u64;
                needed += 1;
            }
            assert_eq!(rounds, needed.max(1), "n={n} cap={cap}");
        }
    });
}

// ----------------------------------------------------- task conservation

/// Drive a scheduler through a random storm of joins, evictions, phase
/// completions and task completions; conservation must hold throughout
/// and the workload must finish.
#[test]
fn prop_no_task_lost_under_random_evictions() {
    forall(120, |rng| {
        let policy = match rng.below(3) {
            0 => ContextPolicy::None,
            1 => ContextPolicy::Partial,
            _ => ContextPolicy::Pervasive,
        };
        let mut sched = Scheduler::new(
            policy,
            ContextRecipe::smollm2_pff(0),
            TransferPlanner::new(1 + rng.below(4) as u32),
        );
        let n_tasks = 1 + rng.below(40) as u64;
        let batch = 1 + rng.below(200) as u64;
        sched.submit_tasks(
            Batcher::new(batch).split(n_tasks * batch, 0, 0),
        );
        let total_inferences = n_tasks * batch;

        let mut next_node = 0u32;
        // In-flight work: (task, worker, remaining phase count, next idx).
        let mut running: Vec<(u64, u32, Vec<PhaseKind>, usize)> = Vec::new();
        let mut guard = 0;
        while !sched.all_done() {
            guard += 1;
            assert!(guard < 100_000, "storm did not converge");
            match rng.below(10) {
                // Join a worker.
                0 | 1 => {
                    let gpu = if rng.chance(0.5) {
                        GpuModel::A10
                    } else {
                        GpuModel::TitanXPascal
                    };
                    let node = Node { id: next_node, gpu };
                    next_node += 1;
                    sched.worker_join(node, guard as f64);
                }
                // Evict a random worker.
                2 => {
                    let ids: Vec<u32> =
                        sched.workers().map(|w| w.id).collect();
                    if !ids.is_empty() {
                        let victim = ids[rng.below(ids.len())];
                        sched.worker_evict(victim);
                        running.retain(|(_, w, _, _)| *w != victim);
                    }
                }
                // Progress one in-flight task by one phase.
                _ => {
                    if running.is_empty() {
                        for d in sched.try_dispatch() {
                            running.push((d.task, d.worker, d.phases, 0));
                        }
                    } else {
                        let i = rng.below(running.len());
                        let (task, worker, phases, next) = &mut running[i];
                        sched.phase_done(*task, *next);
                        *next += 1;
                        if *next == phases.len() {
                            let (attempts, inferences) =
                                sched.task_meta(*task).unwrap();
                            let rec = TaskRecord {
                                task: *task,
                                context: sched.task_context(*task).unwrap_or(0),
                                worker: *worker,
                                gpu: GpuModel::A10,
                                attempts,
                                inferences,
                                dispatched_at: 0.0,
                                completed_at: guard as f64,
                                context_s: 0.0,
                                execute_s: 1.0,
                            };
                            sched.task_done(*task, rec);
                            running.remove(i);
                        }
                    }
                }
            }
            assert!(sched.check_conservation(), "conservation violated");
        }
        let p = sched.progress();
        assert_eq!(p.completed_inferences, total_inferences);
        assert_eq!(p.completed_tasks, n_tasks);
    });
}

// --------------------------------------------------------------- cluster

#[test]
fn prop_cluster_reconcile_converges_to_target() {
    forall(200, |rng| {
        let mut sim;
        let mut t = 0.0;
        // Random walk of targets; after each reconcile availability must
        // equal min(target, pool size).
        for _ in 0..30 {
            let target = rng.below(25) as u32;
            sim = ClusterSim::new(
                pool_20_mixed(),
                LoadTrace::constant(target),
                rng.fork(target as u64),
            );
            t += 1.0;
            let actions = sim.reconcile(t);
            assert_eq!(sim.available(), target.min(20));
            // Grants reference offered nodes only.
            for a in actions {
                if let ClusterAction::Grant(id) = a {
                    assert!(sim.offered_nodes().contains(&id));
                }
            }
        }
    });
}

#[test]
fn prop_cluster_eviction_respects_priority() {
    forall(100, |rng| {
        let mut sim = ClusterSim::new(
            pool_20_mixed(),
            LoadTrace::from_steps(vec![(0.0, 20), (10.0, 10)]),
            rng.fork(3),
        );
        sim.reclaim_priority =
            vec![GpuModel::A10, GpuModel::TitanXPascal];
        sim.reconcile(0.0);
        for id in sim.offered_nodes() {
            sim.mark_held(id);
        }
        let actions = sim.reconcile(10.0);
        // All 10 reclaims must be A10s (10 A10s exist, need exactly 10).
        for a in actions {
            if let ClusterAction::Reclaim(id) = a {
                assert_eq!(sim.node(id).gpu, GpuModel::A10);
            }
        }
    });
}

// ------------------------------------------------------------- tokenizer

#[test]
fn prop_tokenizer_encode_invariants() {
    use pcm::runtime::tokenizer::{HashTokenizer, BOS_ID, EOS_ID, PAD_ID};
    forall(300, |rng| {
        let vocab = 16 + rng.below(8192) as u32;
        let seq = 2 + rng.below(256);
        let tok = HashTokenizer::new(vocab, seq);
        // Random ASCII-ish text.
        let len = rng.below(400);
        let text: String = (0..len)
            .map(|_| {
                let c = rng.below(96) as u8 + 32;
                c as char
            })
            .collect();
        let ids = tok.encode(&text);
        assert_eq!(ids.len(), seq);
        assert_eq!(ids[0], BOS_ID);
        assert!(ids.iter().all(|&i| i < vocab));
        assert!(ids.contains(&EOS_ID));
        // After the first EOS, everything is PAD.
        let eos_pos = ids.iter().position(|&i| i == EOS_ID).unwrap();
        assert!(ids[eos_pos + 1..].iter().all(|&i| i == PAD_ID));
        // Deterministic.
        assert_eq!(tok.encode(&text), ids);
    });
}

// ------------------------------------------------------------- summary

#[test]
fn prop_summary_stats_match_naive_computation() {
    use pcm::util::Summary;
    forall(200, |rng| {
        let n = 1 + rng.below(500);
        let xs: Vec<f64> =
            (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((s.mean() - mean).abs() < 1e-9);
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(s.min(), min);
        assert_eq!(s.max(), max);
        assert!(s.percentile(0.0) >= min && s.percentile(100.0) <= max);
        // Histogram conserves mass.
        let hist = s.histogram(-100.0, 100.0, 10);
        assert_eq!(hist.iter().sum::<usize>(), n);
    });
}

// ----------------------------------------------------------------- json

#[test]
fn prop_json_roundtrip() {
    use pcm::util::Json;
    use std::collections::BTreeMap;

    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.uniform(-1e6, 1e6)).round()),
            3 => {
                let len = rng.below(12);
                Json::Str(
                    (0..len)
                        .map(|_| (rng.below(94) as u8 + 33) as char)
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect(),
            ),
            _ => {
                let mut m = BTreeMap::new();
                for i in 0..rng.below(5) {
                    m.insert(format!("k{i}"), gen(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }

    forall(300, |rng| {
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "roundtrip failed for {text}");
    });
}

// ----------------------------------------------- multi-context caching

const KINDS: [ComponentKind; 5] = [
    ComponentKind::DepsPackage,
    ComponentKind::ModelWeights,
    ComponentKind::FunctionCode,
    ComponentKind::ContextCode,
    ComponentKind::ContextInputs,
];

/// Random multi-context storm: worker cache occupancy must never exceed
/// capacity, at every step, for every worker, under every policy.
#[test]
fn prop_cache_occupancy_never_exceeds_capacity() {
    forall(60, |rng| {
        let policy = match rng.below(3) {
            0 => ContextPolicy::None,
            1 => ContextPolicy::Partial,
            _ => ContextPolicy::Pervasive,
        };
        // 1–30 GB: sometimes fits both contexts, sometimes neither.
        let capacity = (1 + rng.below(30) as u64) * 1_000_000_000;
        let mut sched = Scheduler::with_registry(
            policy,
            vec![
                ContextRecipe::smollm2_pff(0),
                ContextRecipe::custom(1, "big", 5_000_000_000, 10_000_000_000),
            ],
            TransferPlanner::new(1 + rng.below(4) as u32),
            CostModel::default(),
            capacity,
        );
        let n_tasks = 1 + rng.below(30) as u64;
        let batch = 1 + rng.below(100) as u64;
        let tasks: Vec<Task> = (0..n_tasks)
            .map(|i| Task::new(i, i * batch, batch, rng.below(2) as u32))
            .collect();
        sched.submit_tasks(tasks);

        let mut next_node = 0u32;
        let mut running: Vec<(u64, u32, Vec<PhaseKind>, usize)> = Vec::new();
        let mut guard = 0;
        while !sched.all_done() {
            guard += 1;
            assert!(guard < 100_000, "storm did not converge");
            match rng.below(10) {
                0 | 1 => {
                    let gpu = if rng.chance(0.5) {
                        GpuModel::A10
                    } else {
                        GpuModel::TitanXPascal
                    };
                    let node = Node { id: next_node, gpu };
                    next_node += 1;
                    sched.worker_join(node, guard as f64);
                }
                2 => {
                    let ids: Vec<u32> =
                        sched.workers().map(|w| w.id).collect();
                    if !ids.is_empty() {
                        let victim = ids[rng.below(ids.len())];
                        sched.worker_evict(victim);
                        running.retain(|(_, w, _, _)| *w != victim);
                    }
                }
                _ => {
                    if running.is_empty() {
                        for d in sched.try_dispatch() {
                            running.push((d.task, d.worker, d.phases, 0));
                        }
                    } else {
                        let i = rng.below(running.len());
                        let (task, worker, phases, next) = &mut running[i];
                        sched.phase_done(*task, *next);
                        *next += 1;
                        if *next == phases.len() {
                            let (attempts, inferences) =
                                sched.task_meta(*task).unwrap();
                            let rec = TaskRecord {
                                task: *task,
                                context: sched
                                    .task_context(*task)
                                    .unwrap_or(0),
                                worker: *worker,
                                gpu: GpuModel::A10,
                                attempts,
                                inferences,
                                dispatched_at: 0.0,
                                completed_at: guard as f64,
                                context_s: 0.0,
                                execute_s: 1.0,
                            };
                            sched.task_done(*task, rec);
                            running.remove(i);
                        }
                    }
                }
            }
            assert!(
                sched.check_cache_capacity(),
                "cache occupancy exceeded capacity {capacity}"
            );
            assert!(sched.check_conservation());
        }
        assert_eq!(sched.progress().completed_inferences, n_tasks * batch);
    });
}

/// Worker-level LRU property: an insert never evicts the pinned context
/// (nor the context being inserted), pinned components survive intact,
/// and occupancy stays within capacity.
#[test]
fn prop_lru_never_evicts_pinned_context() {
    forall(200, |rng| {
        let capacity = 1_000 + rng.below(100_000) as u64;
        let mut w = Worker::new(
            0,
            Node { id: 0, gpu: GpuModel::A10 },
            0.0,
            capacity,
        );
        for _ in 0..200 {
            let ctx = rng.below(6) as u32;
            let kind = KINDS[rng.below(KINDS.len())];
            let bytes = 1 + rng.below(40_000) as u64;
            let cached = w.cached_contexts_lru();
            let pinned = if cached.is_empty() || rng.chance(0.3) {
                ctx
            } else {
                cached[rng.below(cached.len())]
            };
            let before: Vec<ComponentKind> = KINDS
                .iter()
                .filter(|k| w.has_cached(pinned, **k))
                .copied()
                .collect();
            let (_ok, evicted) =
                w.insert_cached(ctx, kind, bytes, Some(pinned));
            assert!(!evicted.contains(&pinned), "pinned context evicted");
            assert!(!evicted.contains(&ctx), "inserting context evicted");
            for k in &before {
                assert!(
                    w.has_cached(pinned, *k),
                    "pinned context lost component {k:?}"
                );
            }
            assert!(w.cached_bytes_total() <= w.cache_capacity());
        }
    });
}

/// Affinity dispatch: whenever a worker with the task's context
/// materialized is idle, it wins over any number of colder (even much
/// faster) workers, and the plan degenerates to a bare Execute.
#[test]
fn prop_affinity_prefers_materialized_worker() {
    forall(150, |rng| {
        let gpus = [
            GpuModel::A10,
            GpuModel::TitanXPascal,
            GpuModel::H100,
            GpuModel::A40,
        ];
        let mut sched = Scheduler::new(
            ContextPolicy::Pervasive,
            ContextRecipe::smollm2_pff(0),
            TransferPlanner::new(3),
        );
        sched.submit_tasks(vec![
            Task::new(0, 0, 10, 0),
            Task::new(1, 10, 10, 0),
        ]);
        // Warm exactly one worker by running the first task on it.
        let warm_gpu = gpus[rng.below(gpus.len())];
        let warm = sched.worker_join(Node { id: 0, gpu: warm_gpu }, 0.0);
        let d1 = sched.try_dispatch();
        assert_eq!(d1.len(), 1);
        for i in 0..d1[0].phases.len() {
            sched.phase_done(d1[0].task, i);
        }
        sched.task_done(
            d1[0].task,
            TaskRecord {
                task: 0,
                context: 0,
                worker: warm,
                gpu: warm_gpu,
                attempts: 1,
                inferences: 10,
                dispatched_at: 0.0,
                completed_at: 1.0,
                context_s: 0.0,
                execute_s: 1.0,
            },
        );
        // Join 1–6 cold workers with arbitrary (possibly faster) GPUs.
        let n_cold = 1 + rng.below(6);
        for i in 0..n_cold {
            sched.worker_join(
                Node { id: 1 + i as u32, gpu: gpus[rng.below(gpus.len())] },
                1.0,
            );
        }
        let d2 = sched.try_dispatch();
        let mine = d2.iter().find(|d| d.task == 1).unwrap();
        assert_eq!(
            mine.worker, warm,
            "affinity must route to the materialized worker"
        );
        assert_eq!(mine.phases.len(), 1, "warm plan is a bare Execute");
    });
}

// --------------------------------------------------- fair-share deficit

/// DRR starvation bound: while a context has queued tasks, its banked
/// deficit never exceeds one max-task burst (the largest batch it still
/// has queued) — so no tenant can accumulate unbounded priority, and a
/// backlogged tenant is never more than one burst away from service.
/// Checked after every placement round of a random storm, under random
/// weights, batch sizes, joins and evictions.
#[test]
fn prop_fairshare_deficit_bounded_by_one_burst() {
    forall(50, |rng| {
        let w0 = 0.25 + rng.uniform(0.0, 3.75);
        let w1 = 0.25 + rng.uniform(0.0, 3.75);
        let mut sched = Scheduler::with_registry(
            ContextPolicy::Pervasive,
            vec![
                ContextRecipe::smollm2_pff(0).with_weight(w0),
                ContextRecipe::custom(1, "big", 5_000_000_000, 10_000_000_000)
                    .with_weight(w1),
            ],
            TransferPlanner::new(3),
            CostModel::default(),
            (8 + rng.below(17) as u64) * 1_000_000_000,
        );
        let n_tasks = 2 + rng.below(30) as u64;
        let tasks: Vec<Task> = (0..n_tasks)
            .map(|i| {
                Task::new(
                    i,
                    i * 10,
                    1 + rng.below(200) as u64,
                    rng.below(2) as u32,
                )
            })
            .collect();
        sched.submit_tasks(tasks);

        let mut policy = WeightedFairShare::new();
        let check_bound = |sched: &Scheduler, policy: &WeightedFairShare| {
            // Largest still-queued batch per context.
            let mut max_burst = std::collections::BTreeMap::new();
            for q in SchedulerView::new(sched).queued_prefix(usize::MAX) {
                let e = max_burst.entry(q.context).or_insert(0u64);
                *e = (*e).max(q.inferences);
            }
            for ctx in [0u32, 1u32] {
                match max_burst.get(&ctx) {
                    Some(burst) => assert!(
                        policy.deficit(ctx) <= *burst as f64 + 1e-6,
                        "ctx {ctx} deficit {} exceeds burst {burst}",
                        policy.deficit(ctx)
                    ),
                    None => assert_eq!(
                        policy.deficit(ctx),
                        0.0,
                        "drained ctx {ctx} keeps no credit"
                    ),
                }
            }
        };

        let mut next_node = 0u32;
        let mut running: Vec<(u64, u32, usize, usize)> = Vec::new();
        let mut guard = 0;
        while !sched.all_done() {
            guard += 1;
            assert!(guard < 100_000, "storm did not converge");
            match rng.below(10) {
                0 | 1 => {
                    let gpu = if rng.chance(0.5) {
                        GpuModel::A10
                    } else {
                        GpuModel::H100
                    };
                    sched.worker_join(Node { id: next_node, gpu }, guard as f64);
                    next_node += 1;
                }
                2 => {
                    let ids: Vec<u32> =
                        sched.workers().map(|w| w.id).collect();
                    if !ids.is_empty() {
                        let victim = ids[rng.below(ids.len())];
                        sched.worker_evict(victim);
                        running.retain(|(_, w, _, _)| *w != victim);
                    }
                }
                _ => {
                    if running.is_empty() || rng.chance(0.25) {
                        let decisions =
                            policy.place(&SchedulerView::new(&sched));
                        let ds = sched.apply_decisions(decisions);
                        check_bound(&sched, &policy);
                        for d in ds {
                            running.push((d.task, d.worker, d.phases.len(), 0));
                        }
                    } else {
                        let i = rng.below(running.len());
                        let (task, worker, n_phases, next) = &mut running[i];
                        sched.phase_done(*task, *next);
                        *next += 1;
                        if *next == *n_phases {
                            let (_, inferences) =
                                sched.task_meta(*task).unwrap();
                            let ctx = sched.task_context(*task).unwrap_or(0);
                            sched.task_done(
                                *task,
                                TaskRecord {
                                    task: *task,
                                    context: ctx,
                                    worker: *worker,
                                    gpu: GpuModel::A10,
                                    attempts: 1,
                                    inferences,
                                    dispatched_at: 0.0,
                                    completed_at: guard as f64,
                                    context_s: 0.0,
                                    execute_s: 1.0,
                                },
                            );
                            running.remove(i);
                        }
                    }
                }
            }
            assert!(sched.check_conservation());
            assert!(sched.check_cache_capacity());
        }
        assert_eq!(
            sched.progress().completed_tasks,
            n_tasks,
            "fair share completes the whole workload"
        );
    });
}

// ------------------------------------------- node-resident disk caches

/// Reclaim/rejoin storm over a small node pool (so node ids are reused
/// and warm restores actually fire): the node-cache directory's
/// per-node occupancy never exceeds the disk capacity it was recorded
/// with, worker caches stay within capacity, and no task is ever lost —
/// at every step, under every context policy.
#[test]
fn prop_disk_tier_occupancy_respects_node_capacity() {
    forall(50, |rng| {
        let policy = match rng.below(3) {
            0 => ContextPolicy::None,
            1 => ContextPolicy::Partial,
            _ => ContextPolicy::Pervasive,
        };
        let capacity = (8 + rng.below(23) as u64) * 1_000_000_000;
        let mut sched = Scheduler::with_registry(
            policy,
            vec![
                ContextRecipe::smollm2_pff(0),
                ContextRecipe::custom(1, "big", 5_000_000_000, 10_000_000_000),
            ],
            TransferPlanner::new(1 + rng.below(4) as u32),
            CostModel::default(),
            capacity,
        );
        let n_tasks = 1 + rng.below(25) as u64;
        let batch = 1 + rng.below(100) as u64;
        let tasks: Vec<Task> = (0..n_tasks)
            .map(|i| Task::new(i, i * batch, batch, rng.below(2) as u32))
            .collect();
        sched.submit_tasks(tasks);

        // 4 reusable nodes: joins take a free one, evictions free it.
        let mut free_nodes: Vec<u32> = vec![0, 1, 2, 3];
        let mut running: Vec<(u64, u32, Vec<PhaseKind>, usize)> = Vec::new();
        let mut guard = 0;
        while !sched.all_done() {
            guard += 1;
            assert!(guard < 100_000, "storm did not converge");
            match rng.below(10) {
                0 | 1 => {
                    if !free_nodes.is_empty() {
                        let pos = rng.below(free_nodes.len());
                        let node_id = free_nodes.swap_remove(pos);
                        let gpu = if rng.chance(0.5) {
                            GpuModel::A10
                        } else {
                            GpuModel::TitanXPascal
                        };
                        sched.worker_join(
                            Node { id: node_id, gpu },
                            guard as f64,
                        );
                    }
                }
                2 => {
                    let ids: Vec<u32> =
                        sched.workers().map(|w| w.id).collect();
                    if !ids.is_empty() {
                        let victim = ids[rng.below(ids.len())];
                        let node = sched.worker(victim).unwrap().node_id();
                        sched.worker_evict(victim);
                        free_nodes.push(node);
                        running.retain(|(_, w, _, _)| *w != victim);
                    }
                }
                3 if rng.chance(0.2) => {
                    // Occasional content update mid-run.
                    sched.bump_context_version(rng.below(2) as u32);
                }
                _ => {
                    if running.is_empty() {
                        for d in sched.try_dispatch() {
                            running.push((d.task, d.worker, d.phases, 0));
                        }
                    } else {
                        let i = rng.below(running.len());
                        let (task, worker, phases, next) = &mut running[i];
                        sched.phase_done(*task, *next);
                        *next += 1;
                        if *next == phases.len() {
                            let (attempts, inferences) =
                                sched.task_meta(*task).unwrap();
                            let rec = TaskRecord {
                                task: *task,
                                context: sched
                                    .task_context(*task)
                                    .unwrap_or(0),
                                worker: *worker,
                                gpu: GpuModel::A10,
                                attempts,
                                inferences,
                                dispatched_at: 0.0,
                                completed_at: guard as f64,
                                context_s: 0.0,
                                execute_s: 1.0,
                            };
                            sched.task_done(*task, rec);
                            running.remove(i);
                        }
                    }
                }
            }
            assert!(
                sched.check_node_cache_capacity(),
                "disk-tier occupancy exceeded node capacity {capacity}"
            );
            assert!(sched.check_cache_capacity());
            assert!(sched.check_conservation());
        }
        assert_eq!(sched.progress().completed_inferences, n_tasks * batch);
    });
}

/// Version safety of warm restarts: whatever storm of evictions,
/// rejoins and registry version bumps happens, a freshly joined worker
/// only ever holds cached components at exactly the version its node
/// persisted — and that version always equals the current registry
/// version (stale snapshots are dropped, never served, and nothing is
/// invented newer than the disk actually holds).
#[test]
fn prop_warm_restart_never_serves_newer_version_than_persisted() {
    forall(60, |rng| {
        let mut sched = Scheduler::with_registry(
            ContextPolicy::Pervasive,
            vec![
                ContextRecipe::smollm2_pff(0),
                ContextRecipe::custom(1, "big", 2_000_000_000, 4_000_000_000),
            ],
            TransferPlanner::new(3),
            CostModel::default(),
            30_000_000_000,
        );
        let n_tasks = 4 + rng.below(20) as u64;
        let tasks: Vec<Task> = (0..n_tasks)
            .map(|i| Task::new(i, i * 10, 10, rng.below(2) as u32))
            .collect();
        sched.submit_tasks(tasks);

        let mut free_nodes: Vec<u32> = vec![0, 1, 2];
        let mut running: Vec<(u64, u32, Vec<PhaseKind>, usize)> = Vec::new();
        let mut guard = 0;
        while !sched.all_done() && guard < 3_000 {
            guard += 1;
            match rng.below(10) {
                0 | 1 => {
                    if !free_nodes.is_empty() {
                        let pos = rng.below(free_nodes.len());
                        let node_id = free_nodes.swap_remove(pos);
                        let wid = sched.worker_join(
                            Node { id: node_id, gpu: GpuModel::A10 },
                            guard as f64,
                        );
                        // The invariant under test, checked at the only
                        // moment restores happen: join time.
                        let persisted: Vec<(u32, Option<u32>)> = [0u32, 1]
                            .iter()
                            .map(|c| {
                                (*c, sched
                                    .node_caches()
                                    .entry(node_id)
                                    .and_then(|e| e.persisted_version(*c)))
                            })
                            .collect();
                        let w = sched.worker(wid).unwrap();
                        for (ctx, persisted_v) in persisted {
                            let held = KINDS
                                .iter()
                                .filter(|k| w.has_cached(ctx, **k))
                                .count();
                            if held == 0 {
                                continue;
                            }
                            let reg_v =
                                sched.recipe(ctx).unwrap().version;
                            let pv = persisted_v.expect(
                                "restored bytes must come from a snapshot",
                            );
                            assert_eq!(
                                w.cached_version(ctx),
                                pv,
                                "worker version must equal persisted"
                            );
                            assert_eq!(
                                pv, reg_v,
                                "mismatched versions must be dropped, \
                                 not served"
                            );
                        }
                    }
                }
                2 => {
                    let ids: Vec<u32> =
                        sched.workers().map(|w| w.id).collect();
                    if !ids.is_empty() {
                        let victim = ids[rng.below(ids.len())];
                        let node = sched.worker(victim).unwrap().node_id();
                        sched.worker_evict(victim);
                        free_nodes.push(node);
                        running.retain(|(_, w, _, _)| *w != victim);
                    }
                }
                3 => {
                    // Bump while snapshots exist: the next rejoin must
                    // treat them as stale.
                    sched.bump_context_version(rng.below(2) as u32);
                }
                _ => {
                    if running.is_empty() {
                        for d in sched.try_dispatch() {
                            running.push((d.task, d.worker, d.phases, 0));
                        }
                    } else {
                        let i = rng.below(running.len());
                        let (task, worker, phases, next) = &mut running[i];
                        sched.phase_done(*task, *next);
                        *next += 1;
                        if *next == phases.len() {
                            let (attempts, inferences) =
                                sched.task_meta(*task).unwrap();
                            let rec = TaskRecord {
                                task: *task,
                                context: sched
                                    .task_context(*task)
                                    .unwrap_or(0),
                                worker: *worker,
                                gpu: GpuModel::A10,
                                attempts,
                                inferences,
                                dispatched_at: 0.0,
                                completed_at: guard as f64,
                                context_s: 0.0,
                                execute_s: 1.0,
                            };
                            sched.task_done(*task, rec);
                            running.remove(i);
                        }
                    }
                }
            }
            assert!(sched.check_node_cache_capacity());
            assert!(sched.check_conservation());
        }
    });
}

// -------------------------------------------------------------- sim end

#[test]
fn prop_sim_runs_complete_for_any_batch_and_policy() {
    use pcm::coordinator::{SimConfig, SimDriver};
    forall(25, |rng| {
        let policy = match rng.below(3) {
            0 => ContextPolicy::None,
            1 => ContextPolicy::Partial,
            _ => ContextPolicy::Pervasive,
        };
        let batch = [1u64, 7, 50, 333, 1000][rng.below(5)];
        let total = 500 + rng.below(2_000) as u64;
        let mut cfg = SimConfig::new(
            "prop",
            policy,
            batch,
            pool_20_mixed(),
            LoadTrace::constant(1 + rng.below(20) as u32),
            rng.next_u64(),
        );
        cfg.apps[0].total_inferences = total;
        let out = SimDriver::new(cfg).run();
        assert_eq!(out.summary.completed_inferences, total);
    });
}

/// Sharding is an implementation detail of the coordinator, not of the
/// workload: on small random multi-app storms, the merged telemetry of a
/// two-shard run must agree with the single-shard run on every
/// scheduling-robust projection (tasks and inferences submitted and
/// completed, overall and per context), and the sharded trace must
/// replay cleanly through the invariant checker. Wall-clock-dependent
/// counters (cache hits, round timings) legitimately differ when the
/// stochastic cost model places tasks differently, so they are not
/// compared here — exact trace-level parity on a symmetric workload is
/// `pcm experiment shards`' job.
#[test]
fn prop_sharded_telemetry_matches_single_shard() {
    use pcm::coordinator::{AppSpec, SimConfig, SimDriver};
    use pcm::obs::{check_events, MemorySink, Telemetry, TraceHandle};
    use std::sync::{Arc, Mutex};

    forall(12, |rng| {
        let n_apps = 2 + rng.below(2) as u32; // 2..=3 contexts
        let apps: Vec<AppSpec> = (0..n_apps)
            .map(|c| AppSpec {
                recipe: ContextRecipe::custom(
                    c,
                    format!("prop-ctx{c}"),
                    200_000_000 + rng.below(800_000_000) as u64,
                    500_000_000 + rng.below(2_000_000_000) as u64,
                ),
                total_inferences: 100 + rng.below(400) as u64,
                batch_size: 10 + rng.below(40) as u64,
            })
            .collect();
        let nodes = 2 + rng.below(7) as u32; // 2..=8 nodes
        let seed = rng.next_u64();
        let run = |shards: usize| {
            let sink = Arc::new(Mutex::new(MemorySink::unbounded()));
            let cfg = SimConfig::builder(
                format!("prop_shard{shards}"),
                ContextPolicy::Pervasive,
                (0..nodes).map(|id| Node { id, gpu: GpuModel::A10 }).collect(),
                LoadTrace::constant(nodes),
                seed,
            )
            .apps(apps.clone())
            .shards(shards)
            .trace_sink(TraceHandle::from_shared(sink.clone()))
            .build()
            .expect("prop config is valid");
            let out = SimDriver::new(cfg).run();
            let events =
                sink.lock().map(|s| s.events()).unwrap_or_default();
            (out, events)
        };
        let (single, _) = run(1);
        let (sharded, sharded_events) = run(2);

        // The sharded trace replays cleanly through every invariant.
        let violations = check_events(&sharded_events);
        assert!(violations.is_empty(), "sharded trace: {violations:?}");

        // Merged telemetry agrees on every scheduling-robust counter.
        let t2 = Telemetry::from_events(&sharded_events);
        assert_eq!(sharded.shards, 2);
        assert_eq!(t2.submitted as usize, single.records.len());
        assert_eq!(t2.completed as usize, single.records.len());
        assert_eq!(
            t2.completed_inferences,
            single.summary.completed_inferences
        );
        assert_eq!(
            single.summary.completed_inferences,
            sharded.summary.completed_inferences
        );
        // Per-context totals survive the merge.
        for c in 0..n_apps {
            let per = |recs: &[pcm::coordinator::TaskRecord]| {
                recs.iter()
                    .filter(|r| r.context == c)
                    .map(|r| r.inferences)
                    .sum::<u64>()
            };
            assert_eq!(per(&single.records), per(&sharded.records), "ctx {c}");
        }
    });
}

// ----------------------------------------------------- incremental indexes

/// The indexed-dispatch refactor maintains warm-worker sets, per-context
/// queue/in-flight/completed counters, batch-size multisets, ready-order
/// keys, peer-kind counts, and a memoized estimate table incrementally
/// across every mutation choke point. After ANY interleaving of enqueue,
/// dispatch (greedy or prefetching), phase progress, completion,
/// eviction, cached-node rejoin, reclaim-forecast update, and context
/// version bump, each index must exactly match a from-scratch
/// recomputation — `check_index_consistency` rebuilds all of them from
/// ground-truth scans and compares.
#[test]
fn prop_indexed_state_matches_scan_after_any_interleaving() {
    use pcm::coordinator::policy::WarmPrefetch;

    forall(60, |rng| {
        let policy = match rng.below(3) {
            0 => ContextPolicy::None,
            1 => ContextPolicy::Partial,
            _ => ContextPolicy::Pervasive,
        };
        let mut sched = Scheduler::with_registry(
            policy,
            vec![
                ContextRecipe::smollm2_pff(0),
                ContextRecipe::custom(1, "big", 5_000_000_000, 10_000_000_000),
                ContextRecipe::custom(2, "side", 1_000_000, 2_000_000),
            ],
            TransferPlanner::new(1 + rng.below(4) as u32),
            CostModel::default(),
            (8 + rng.below(17) as u64) * 1_000_000_000,
        );
        let gpus = [
            GpuModel::A10,
            GpuModel::TitanXPascal,
            GpuModel::H100,
            GpuModel::A40,
        ];
        let mut next_task = 0u64;
        for _ in 0..1 + rng.below(10) {
            sched.submit_tasks(vec![Task::new(
                next_task,
                next_task * 10,
                1 + rng.below(100) as u64,
                rng.below(3) as u32,
            )]);
            next_task += 1;
        }

        // In-flight tasks AND prefetches: (id, worker, phases, next).
        let mut running: Vec<(u64, u32, usize, usize)> = Vec::new();
        let steps = 200 + rng.below(200);
        for step in 0..steps {
            sched.set_clock_hint(step as f64);
            match rng.below(12) {
                // Enqueue a burst mid-storm.
                0 => {
                    let burst = 1 + rng.below(5);
                    let tasks: Vec<Task> = (0..burst)
                        .map(|_| {
                            let t = Task::new(
                                next_task,
                                next_task * 10,
                                1 + rng.below(100) as u64,
                                rng.below(3) as u32,
                            );
                            next_task += 1;
                            t
                        })
                        .collect();
                    sched.submit_tasks(tasks);
                }
                // Join — the tiny node-id space forces rejoins onto
                // nodes with persisted caches (restore replay).
                1 | 2 => {
                    let node =
                        Node { id: rng.below(6) as u32, gpu: gpus[rng.below(4)] };
                    if !sched.workers().any(|w| w.node_id() == node.id) {
                        sched.worker_join(node, step as f64);
                    }
                }
                // Evict a random worker (requeues its task, drops its
                // prefetch, persists its cache).
                3 => {
                    let ids: Vec<u32> = sched.workers().map(|w| w.id).collect();
                    if !ids.is_empty() {
                        let victim = ids[rng.below(ids.len())];
                        sched.worker_evict(victim);
                        running.retain(|(_, w, _, _)| *w != victim);
                    }
                }
                // Reclaim forecast set/cleared, sometimes in the past.
                4 => {
                    let hint = if rng.chance(0.3) {
                        None
                    } else {
                        Some(step as f64 + rng.below(500) as f64 - 50.0)
                    };
                    sched.set_node_reclaim_hint(rng.below(6) as u32, hint);
                }
                // Version bump: every cached copy invalidated at once.
                5 => {
                    sched.bump_context_version(rng.below(3) as u32);
                }
                // Dispatch through the default greedy path or through a
                // prefetching policy (exercises prefetch counters).
                6 | 7 => {
                    if rng.chance(0.5) {
                        for d in sched.try_dispatch() {
                            running.push((d.task, d.worker, d.phases.len(), 0));
                        }
                    } else {
                        let mut pf = WarmPrefetch::default();
                        let decisions = pf.place(&SchedulerView::new(&sched));
                        for d in sched.apply_decisions(decisions) {
                            running.push((d.task, d.worker, d.phases.len(), 0));
                        }
                    }
                }
                // Progress or complete something in flight.
                _ => {
                    if !running.is_empty() {
                        let i = rng.below(running.len());
                        let (id, worker, n_phases, next) = &mut running[i];
                        sched.phase_done(*id, *next);
                        *next += 1;
                        if *next == *n_phases {
                            if !Scheduler::is_prefetch_id(*id) {
                                let (_, inferences) =
                                    sched.task_meta(*id).unwrap();
                                let ctx = sched.task_context(*id).unwrap();
                                sched.task_done(
                                    *id,
                                    TaskRecord {
                                        task: *id,
                                        context: ctx,
                                        worker: *worker,
                                        gpu: GpuModel::A10,
                                        attempts: 1,
                                        inferences,
                                        dispatched_at: 0.0,
                                        completed_at: step as f64,
                                        context_s: 0.0,
                                        execute_s: 1.0,
                                    },
                                );
                            }
                            running.remove(i);
                        }
                    }
                }
            }
            assert!(sched.check_conservation());
            assert!(sched.check_cache_capacity());
            assert!(
                sched.check_index_consistency(),
                "incremental index diverged from scan truth at step {step}"
            );
        }
    });
}
