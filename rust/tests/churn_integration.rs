//! Churn-subsystem integration: recorded reclamation traces replay
//! deterministically through the full sim driver, and the node-resident
//! cache directory actually changes what a rejoined worker pays.

use pcm::cluster::node::pool_20_mixed;
use pcm::cluster::{LoadTrace, NodeAvailabilityTrace};
use pcm::coordinator::{ContextPolicy, PolicyKind, SimConfig, SimDriver};
use pcm::experiments::churn;
use pcm::util::Rng;

/// A churn config over an explicit (possibly JSON-loaded) node trace.
fn cfg_with_trace(trace: NodeAvailabilityTrace, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(
        "churn_replay",
        ContextPolicy::Pervasive,
        50,
        pool_20_mixed(),
        LoadTrace::constant(20),
        seed,
    );
    cfg.apps[0].total_inferences = 8_000;
    cfg.node_trace = Some(trace);
    cfg
}

/// Record a storm to a JSON file on disk, load it back, and drive two
/// full simulations from the loaded copy: the replay must be lossless
/// and the runs bit-identical.
#[test]
fn recorded_trace_replays_deterministically() {
    let nodes: Vec<u32> = (0..20).collect();
    let storm = NodeAvailabilityTrace::storm(
        &nodes,
        120.0,
        3,
        40.0,
        60.0,
        4,
        &mut Rng::new(17),
    );
    let path = std::env::temp_dir()
        .join(format!("pcm-churn-trace-{}.json", std::process::id()));
    std::fs::write(&path, storm.to_json()).expect("trace written");
    let loaded = NodeAvailabilityTrace::from_json(
        &std::fs::read_to_string(&path).expect("trace read"),
    )
    .expect("trace parses");
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, storm, "disk roundtrip is lossless");

    let a = SimDriver::new(cfg_with_trace(loaded.clone(), 3)).run();
    let b = SimDriver::new(cfg_with_trace(loaded, 3)).run();
    assert_eq!(a.summary.completed_inferences, 8_000);
    assert_eq!(a.summary.exec_time_s, b.summary.exec_time_s);
    assert_eq!(a.summary.evictions, b.summary.evictions);
    assert_eq!(a.warm_started_workers, b.warm_started_workers);
    assert_eq!(
        a.cache.totals().staged_bytes,
        b.cache.totals().staged_bytes
    );
    assert!(a.summary.evictions > 0, "the storm must bite");
}

/// The same storm with node-cache warm starts must re-transfer fewer
/// bytes than a hypothetical cold rejoin — checked indirectly: every
/// warm-started worker exists in the records and restored components
/// were never charged as misses.
#[test]
fn warm_started_workers_restore_instead_of_restaging() {
    let mut cfg = cfg_with_trace(
        NodeAvailabilityTrace::storm(
            &(0..20).collect::<Vec<u32>>(),
            140.0,
            2,
            50.0,
            60.0,
            5,
            &mut Rng::new(4),
        ),
        9,
    );
    // Enough backlog that both waves' rejoins still find queued work
    // (the factory declines rejoins once the tail no longer needs them).
    cfg.apps[0].total_inferences = 12_000;
    let out = SimDriver::new(cfg).run();
    assert_eq!(out.summary.completed_inferences, 12_000);
    assert!(
        !out.warm_started_workers.is_empty(),
        "rejoins must warm-start"
    );
    let c = out.cache.ctx(0);
    assert!(c.warm_restored > 0);
    assert!(
        c.warm_restart_hit_rate() > 0.0,
        "hit rate reflects restored components: {c:?}"
    );
    // Warm-started workers' first tasks must be cheaper on context
    // acquisition than cold workers' first tasks (the §7 payoff).
    let (warm, cold) = churn::first_task_context_split(&out);
    assert!(!warm.is_empty() && !cold.is_empty());
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    assert!(
        mean(&warm) < mean(&cold),
        "warm {:.2}s !< cold {:.2}s",
        mean(&warm),
        mean(&cold)
    );
}

/// Risk-aware placement under the staging-time storm re-transfers
/// fewer bytes than greedy — the churn-smoke CI assertion, from the
/// library instead of the CLI.
#[test]
fn riskaware_retransfers_fewer_bytes_than_greedy() {
    let greedy = SimDriver::new(churn::bytes_config(
        PolicyKind::Greedy,
        42,
        churn::DEFAULT_INFERENCES_PER_APP,
    ))
    .run();
    let risk = SimDriver::new(churn::bytes_config(
        PolicyKind::RiskAware,
        42,
        churn::DEFAULT_INFERENCES_PER_APP,
    ))
    .run();
    assert_eq!(
        greedy.summary.completed_inferences,
        risk.summary.completed_inferences,
        "both policies finish the workload"
    );
    let (gb, rb) = (
        greedy.cache.totals().staged_bytes,
        risk.cache.totals().staged_bytes,
    );
    assert!(
        rb < gb,
        "riskaware staged {rb} bytes, greedy {gb} — risk awareness must \
         save transfers"
    );
}
