//! Coordinator integration: full simulated runs across policies, batch
//! sizes, eviction scenarios, and pool shapes — the cross-module behavior
//! the paper's claims rest on (all scaled down for test speed).

use pcm::cluster::node::{full_cluster, pool_20_mixed};
use pcm::cluster::{GpuModel, LoadTrace};
use pcm::coordinator::{ContextPolicy, SimConfig, SimDriver};
use pcm::util::Rng;

fn cfg(
    name: &str,
    policy: ContextPolicy,
    batch: u64,
    inferences: u64,
) -> SimConfig {
    let mut c = SimConfig::new(
        name,
        policy,
        batch,
        pool_20_mixed(),
        LoadTrace::constant(20),
        11,
    );
    c.apps[0].total_inferences = inferences;
    c
}

#[test]
fn all_policies_complete_the_workload() {
    for policy in [
        ContextPolicy::None,
        ContextPolicy::Partial,
        ContextPolicy::Pervasive,
    ] {
        let out = SimDriver::new(cfg("t", policy, 100, 3_000)).run();
        assert_eq!(out.summary.completed_inferences, 3_000, "{policy:?}");
        assert_eq!(out.records.len(), 30, "{policy:?}");
    }
}

#[test]
fn batch_sweep_pervasive_flattens_overhead() {
    // Effort 4's key observation: with pervasive context management the
    // batch-size penalty collapses — B=10 and B=100 land close together,
    // while partial context pays brutally at tiny batches. (B is kept ≤
    // inferences/pool so straggling doesn't confound the comparison.)
    let perv_small =
        SimDriver::new(cfg("p10", ContextPolicy::Pervasive, 10, 10_000)).run();
    let perv_mid =
        SimDriver::new(cfg("p100", ContextPolicy::Pervasive, 100, 10_000))
            .run();
    let part_small =
        SimDriver::new(cfg("q10", ContextPolicy::Partial, 10, 10_000)).run();
    let ratio_perv =
        perv_small.summary.exec_time_s / perv_mid.summary.exec_time_s;
    let ratio_part =
        part_small.summary.exec_time_s / perv_mid.summary.exec_time_s;
    assert!(ratio_perv < 1.5, "pervasive small-batch penalty {ratio_perv}");
    assert!(ratio_part > 2.0, "partial small-batch penalty {ratio_part}");
}

#[test]
fn task_exec_times_shrink_under_pervasive() {
    // Figure 5 / Table 2: pervasive mean ≪ partial mean at batch 1.
    let perv =
        SimDriver::new(cfg("p1", ContextPolicy::Pervasive, 1, 1_000)).run();
    let part =
        SimDriver::new(cfg("q1", ContextPolicy::Partial, 1, 1_000)).run();
    assert!(
        perv.summary.task_mean_s * 5.0 < part.summary.task_mean_s,
        "pervasive {} vs partial {}",
        perv.summary.task_mean_s,
        part.summary.task_mean_s
    );
    assert!(perv.summary.task_std_s < part.summary.task_std_s);
}

#[test]
fn drain_scenario_pervasive_wastes_less() {
    // Figure 6: under a drain, pervasive@100 discards less in-flight work
    // per eviction than partial@1000 (20 × 100 vs 20 × 1000 in the paper).
    let mk = |name: &str, policy, batch| {
        let mut c = SimConfig::new(
            name,
            policy,
            batch,
            pool_20_mixed(),
            LoadTrace::drain(20, 300.0, 30.0),
            13,
        );
        c.reclaim_priority = vec![GpuModel::A10, GpuModel::TitanXPascal];
        c.apps[0].total_inferences = 20_000;
        c
    };
    let s = SimDriver::new(mk("ps", ContextPolicy::Pervasive, 100)).run();
    let p = SimDriver::new(mk("pp", ContextPolicy::Partial, 1_000)).run();
    assert!(s.summary.evictions > 0 && p.summary.evictions > 0);
    assert!(
        s.summary.evicted_inferences < p.summary.evicted_inferences,
        "pervasive discards less: {} vs {}",
        s.summary.evicted_inferences,
        p.summary.evicted_inferences
    );
}

#[test]
fn diurnal_full_cluster_run_adapts() {
    // Figure 7 shape: throughput tracks worker availability.
    let mut rng = Rng::new(7);
    let trace = LoadTrace::diurnal(10.0, 6.0 * 3600.0, 120.0, 5, 40, &mut rng);
    let mut c = SimConfig::new(
        "diurnal",
        ContextPolicy::Pervasive,
        100,
        full_cluster(),
        trace,
        7,
    );
    c.apps[0].total_inferences = 30_000;
    c.start_gate_fraction = 0.0;
    let out = SimDriver::new(c).run();
    assert_eq!(out.summary.completed_inferences, 30_000);
    assert!(out.summary.avg_workers > 5.0);
    // Worker count varies over the run (opportunistic wobble).
    let ws: Vec<u32> = out.series.iter().map(|p| p.connected_workers).collect();
    let min = ws.iter().min().unwrap();
    let max = ws.iter().max().unwrap();
    assert!(max > min, "availability must fluctuate: {min}..{max}");
}

#[test]
fn heterogeneous_pool_fast_gpus_do_more_tasks() {
    // §5.3.2: the 1-task-per-worker policy routes more work to fast GPUs.
    let out =
        SimDriver::new(cfg("h", ContextPolicy::Pervasive, 100, 20_000)).run();
    let mut a10 = 0u64;
    let mut titan = 0u64;
    for r in &out.records {
        match r.gpu {
            GpuModel::A10 => a10 += 1,
            GpuModel::TitanXPascal => titan += 1,
            _ => {}
        }
    }
    assert!(
        a10 > titan,
        "A10s (2x faster) must complete more tasks: {a10} vs {titan}"
    );
}

#[test]
fn eviction_mid_run_loses_no_inferences() {
    // Work conservation under a brutal shrink-then-recover cycle.
    let mut c = SimConfig::new(
        "shrink",
        ContextPolicy::Pervasive,
        50,
        pool_20_mixed(),
        LoadTrace::from_steps(vec![(0.0, 20), (100.0, 3), (2_000.0, 20)]),
        17,
    );
    c.apps[0].total_inferences = 10_000;
    let out = SimDriver::new(c).run();
    assert_eq!(out.summary.completed_inferences, 10_000);
    assert!(out.summary.evictions >= 10);
    // Attempts reflect re-runs.
    assert!(out.records.iter().any(|r| r.attempts > 1));
}

#[test]
fn metrics_series_is_monotone_in_completions() {
    let out =
        SimDriver::new(cfg("m", ContextPolicy::Pervasive, 100, 5_000)).run();
    let mut last = 0u64;
    for p in &out.series {
        assert!(p.completed_inferences >= last);
        last = p.completed_inferences;
    }
    assert_eq!(last, 5_000);
}

#[test]
fn naive_policy_is_overhead_dominated() {
    // pv1's pathology: everyone hammers the shared FS + internet per task.
    let out = SimDriver::new(cfg("n", ContextPolicy::None, 100, 4_000)).run();
    let ctx: f64 = out.records.iter().map(|r| r.context_s).sum();
    let exec: f64 = out.records.iter().map(|r| r.execute_s).sum();
    assert!(
        ctx > exec,
        "naive scaling must be overhead-dominated: ctx={ctx:.0} exec={exec:.0}"
    );
}

#[test]
fn pervasive_is_execution_dominated() {
    let out =
        SimDriver::new(cfg("pd", ContextPolicy::Pervasive, 100, 10_000)).run();
    let ctx: f64 = out.records.iter().map(|r| r.context_s).sum();
    let exec: f64 = out.records.iter().map(|r| r.execute_s).sum();
    assert!(
        exec > 3.0 * ctx,
        "pervasive must be execution-dominated: ctx={ctx:.0} exec={exec:.0}"
    );
}

#[test]
fn start_gate_produces_comparable_measurements() {
    // The 95% gate (§6.2) exists so exec time measures steady-state work,
    // not pool ramp-up. started_at must be after the first join and
    // before the first completion.
    let out =
        SimDriver::new(cfg("g", ContextPolicy::Pervasive, 100, 2_000)).run();
    assert!(out.started_at > 0.0);
    let first_done = out
        .records
        .iter()
        .map(|r| r.completed_at)
        .fold(f64::INFINITY, f64::min);
    assert!(out.started_at < first_done);
}
