//! Golden decision-parity tests for `AffinityGreedy`.
//!
//! The policy refactor's contract is that the default policy makes
//! *bit-for-bit identical* placement decisions to the pre-refactor
//! monolithic `Scheduler::try_dispatch`. `reference_greedy` below is a
//! verbatim port of that original algorithm (same warm-pairing
//! look-ahead, same FIFO affinity scoring with identical float
//! comparisons and tie-breaks); the tests replay it side by side with
//! `AffinityGreedy` across randomized multi-tenant storms and a
//! hand-traceable scenario, asserting identical `(task, worker)`
//! assignment sequences every dispatch round.

use pcm::cluster::{GpuModel, Node};
use pcm::coordinator::policy::{
    AffinityGreedy, PlacementDecision, PlacementPolicy, SchedulerView,
};
use pcm::coordinator::{
    ContextPolicy, ContextRecipe, CostModel, PolicyKind, Scheduler, Task,
    TaskId, TaskRecord, TransferPlanner, WorkerId,
};
use pcm::experiments::mixed;
use pcm::util::Rng;

/// The pre-refactor warm-pairing look-ahead depth.
const LOOKAHEAD: usize = 64;

/// Verbatim port of the pre-policy `Scheduler::try_dispatch` decision
/// logic (phases 1 + 2), expressed over the read-only view.
fn reference_greedy(view: &SchedulerView) -> Vec<(TaskId, WorkerId)> {
    let mut paired = Vec::new();
    let mut queue = view.queued_prefix(usize::MAX);
    if queue.is_empty() {
        return paired;
    }
    let mut idle = view.idle_workers();
    if idle.is_empty() {
        return paired;
    }

    // Warm pairing with bounded look-ahead over the live queue.
    let mut i = 0;
    while i < idle.len() {
        let wid = idle[i];
        let mut found = None;
        for (pos, q) in queue.iter().enumerate().take(LOOKAHEAD) {
            if view.warm_for(wid, q.context) {
                found = Some(pos);
                break;
            }
        }
        if let Some(pos) = found {
            let q = queue.remove(pos);
            let wid = idle.remove(i);
            paired.push((q.task, wid));
        } else {
            i += 1;
        }
    }

    // FIFO + affinity scoring with the original replace semantics.
    for q in queue {
        if idle.is_empty() {
            break;
        }
        let mut best: Option<(usize, f64)> = None;
        for (i, wid) in idle.iter().enumerate() {
            let est = view.acquisition_estimate_s(*wid, q.context);
            let replace = match &best {
                None => true,
                Some((bi, best_est)) => {
                    let b_speed = view.worker_speed(idle[*bi]);
                    match est.partial_cmp(best_est).unwrap() {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Greater => false,
                        std::cmp::Ordering::Equal => match b_speed
                            .partial_cmp(&view.worker_speed(*wid))
                            .unwrap()
                        {
                            std::cmp::Ordering::Less => true,
                            std::cmp::Ordering::Greater => false,
                            std::cmp::Ordering::Equal => *wid < idle[*bi],
                        },
                    }
                }
            };
            if replace {
                best = Some((i, est));
            }
        }
        let (best_i, _) = best.expect("idle is non-empty");
        paired.push((q.task, idle.swap_remove(best_i)));
    }
    paired
}

fn assigns_of(decisions: &[PlacementDecision]) -> Vec<(TaskId, WorkerId)> {
    decisions
        .iter()
        .map(|d| match d {
            PlacementDecision::Assign { task, worker } => (*task, *worker),
            other => panic!("greedy must only Assign, got {other:?}"),
        })
        .collect()
}

fn record(task: TaskId, worker: WorkerId, n: u64, ctx: u32) -> TaskRecord {
    TaskRecord {
        task,
        context: ctx,
        worker,
        gpu: GpuModel::A10,
        attempts: 1,
        inferences: n,
        dispatched_at: 0.0,
        completed_at: 1.0,
        context_s: 0.0,
        execute_s: 1.0,
    }
}

/// Drive a randomized multi-tenant storm; at every dispatch round the
/// extracted policy must reproduce the reference decisions exactly.
#[test]
fn golden_affinity_greedy_matches_pre_refactor_dispatch() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9) ^ 0x601d);
        let policy = match rng.below(3) {
            0 => ContextPolicy::None,
            1 => ContextPolicy::Partial,
            _ => ContextPolicy::Pervasive,
        };
        // 8–24 GB caches: sometimes both contexts fit, sometimes not.
        let capacity = (8 + rng.below(17) as u64) * 1_000_000_000;
        let mut sched = Scheduler::with_registry(
            policy,
            vec![
                ContextRecipe::smollm2_pff(0),
                ContextRecipe::custom(1, "big", 5_000_000_000, 10_000_000_000),
            ],
            TransferPlanner::new(1 + rng.below(4) as u32),
            CostModel::default(),
            capacity,
        );
        let n_tasks = 5 + rng.below(40) as u64;
        let tasks: Vec<Task> = (0..n_tasks)
            .map(|i| {
                Task::new(i, i * 10, 1 + rng.below(100) as u64, rng.below(2) as u32)
            })
            .collect();
        sched.submit_tasks(tasks);

        let gpus =
            [GpuModel::A10, GpuModel::TitanXPascal, GpuModel::H100, GpuModel::A40];
        let mut next_node = 0u32;
        let mut running: Vec<(u64, u32, usize, usize)> = Vec::new();
        let mut guard = 0;
        while !sched.all_done() {
            guard += 1;
            assert!(guard < 100_000, "storm did not converge (seed {seed})");
            match rng.below(10) {
                0 | 1 => {
                    let node = Node {
                        id: next_node,
                        gpu: gpus[rng.below(gpus.len())],
                    };
                    next_node += 1;
                    sched.worker_join(node, guard as f64);
                }
                2 => {
                    let ids: Vec<u32> = sched.workers().map(|w| w.id).collect();
                    if !ids.is_empty() {
                        let victim = ids[rng.below(ids.len())];
                        sched.worker_evict(victim);
                        running.retain(|(_, w, _, _)| *w != victim);
                    }
                }
                _ => {
                    // Dispatch rounds also fire with tasks in flight, so
                    // parity is checked with partially-idle pools too.
                    if running.is_empty() || rng.chance(0.25) {
                        // THE PARITY CHECK: reference vs extracted policy
                        // on the same frozen view, then execute.
                        let expect = reference_greedy(&SchedulerView::new(&sched));
                        let mut greedy = AffinityGreedy::new();
                        let decisions =
                            greedy.place(&SchedulerView::new(&sched));
                        assert_eq!(
                            assigns_of(&decisions),
                            expect,
                            "decision divergence (seed {seed}, round {guard})"
                        );
                        // try_dispatch (the default policy) must agree too.
                        let ds = sched.try_dispatch();
                        let got: Vec<(u64, u32)> =
                            ds.iter().map(|d| (d.task, d.worker)).collect();
                        assert_eq!(got, expect, "try_dispatch divergence");
                        for d in ds {
                            running.push((d.task, d.worker, d.phases.len(), 0));
                        }
                    } else {
                        let i = rng.below(running.len());
                        let (task, worker, n_phases, next) = &mut running[i];
                        sched.phase_done(*task, *next);
                        *next += 1;
                        if *next == *n_phases {
                            let (_, inferences) =
                                sched.task_meta(*task).unwrap();
                            let ctx = sched.task_context(*task).unwrap();
                            sched.task_done(
                                *task,
                                record(*task, *worker, inferences, ctx),
                            );
                            running.remove(i);
                        }
                    }
                }
            }
            assert!(sched.check_conservation());
            assert!(sched.check_cache_capacity());
        }
    }
}

/// End-to-end: the default scheduler and an explicit `--policy greedy`
/// scheduler produce identical mixed-experiment outcomes (the
/// `with_policy` plumbing is an identity for the default).
#[test]
fn golden_mixed_run_identical_under_explicit_greedy() {
    let base = mixed::run_mixed(42, 500);
    let explicit = mixed::run_mixed_with(42, 500, PolicyKind::Greedy);
    for (a, b) in base.iter().zip(&explicit) {
        assert_eq!(a.outcome.summary.exec_time_s, b.outcome.summary.exec_time_s);
        assert_eq!(a.outcome.summary.completed_inferences,
                   b.outcome.summary.completed_inferences);
        assert_eq!(a.outcome.cache.per_context, b.outcome.cache.per_context);
    }
}

/// Hand-traceable scenario: warm pairing wins over a faster cold
/// worker, and the remaining task goes to the fastest cold worker.
#[test]
fn golden_hand_traced_warm_pairing_and_fifo() {
    let mut s = Scheduler::new(
        ContextPolicy::Pervasive,
        ContextRecipe::smollm2_pff(0),
        TransferPlanner::new(3),
    );
    s.submit_tasks(vec![
        Task::new(0, 0, 10, 0),
        Task::new(1, 10, 10, 0),
        Task::new(2, 20, 10, 0),
    ]);
    let slow = s.worker_join(Node { id: 0, gpu: GpuModel::TitanXPascal }, 0.0);
    let d1 = s.try_dispatch();
    assert_eq!(d1.len(), 1);
    assert_eq!(d1[0].task, 0);
    for i in 0..d1[0].phases.len() {
        s.phase_done(d1[0].task, i);
    }
    s.task_done(d1[0].task, record(0, slow, 10, 0));

    // A much faster cold worker joins; warm pairing still hands the
    // next task to the warm slow worker, FIFO gives the other task to
    // the fast cold one.
    let fast = s.worker_join(Node { id: 1, gpu: GpuModel::H100 }, 1.0);
    let d2 = s.try_dispatch();
    let got: Vec<(u64, u32)> = d2.iter().map(|d| (d.task, d.worker)).collect();
    assert_eq!(got, vec![(1, slow), (2, fast)]);
    assert_eq!(d2[0].phases.len(), 1, "warm plan is a bare Execute");
}
