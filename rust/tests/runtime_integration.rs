//! Runtime integration: load real AOT artifacts, execute, and match the
//! Python-exported golden logits — the cross-language numerics oracle.
//!
//! Requires `make artifacts` (skips cleanly when artifacts are absent,
//! e.g. in a fresh checkout).

use pcm::runtime::{
    manifest::default_artifacts_dir, HashTokenizer, InferenceEngine,
    Manifest, ModelContext, WeightStore,
};
use pcm::util::Json;

fn manifest_or_skip() -> Option<Manifest> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest loads"))
}

fn read_json(m: &Manifest, file: &str) -> Json {
    Json::parse(&std::fs::read_to_string(m.path_of(file)).unwrap()).unwrap()
}

#[test]
fn manifest_loads_and_validates() {
    let Some(m) = manifest_or_skip() else { return };
    assert!(m.profiles.contains_key("tiny"));
    assert!(m.profiles.contains_key("small"));
}

#[test]
fn weights_stage_and_are_finite() {
    let Some(m) = manifest_or_skip() else { return };
    let p = m.profile("tiny").unwrap();
    let w = WeightStore::load(p, m.path_of(&p.weights.file)).unwrap();
    assert_eq!(w.total_bytes() as u64, p.weights.bytes);
    w.check_finite().unwrap();
}

#[test]
fn tiny_model_matches_python_golden_logits() {
    let Some(m) = manifest_or_skip() else { return };
    let p = m.profile("tiny").unwrap().clone();
    let ctx = ModelContext::materialize(&m, "tiny", &p.batch_sizes).unwrap();

    let golden = read_json(&m, &p.golden);
    for case in golden.req("cases").unwrap().as_array().unwrap() {
        let batch = case.req("batch").unwrap().as_usize().unwrap();
        let tokens: Vec<i32> = case
            .req("tokens")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .flat_map(|row| row.as_array().unwrap().iter())
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        let want: Vec<Vec<f64>> = case
            .req("logits")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|row| {
                row.as_array()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_f64().unwrap())
                    .collect()
            })
            .collect();

        let got = ctx.execute_tokens(&tokens, batch).unwrap();
        assert_eq!(got.len(), want.len());
        for (g_row, w_row) in got.iter().zip(&want) {
            for (g, w) in g_row.iter().zip(w_row) {
                assert!(
                    (*g as f64 - w).abs() < 1e-3,
                    "logit mismatch: rust={g} python={w} (batch {batch})"
                );
            }
        }
    }
}

#[test]
fn rust_tokenizer_matches_golden_tokens() {
    // The golden file stores Python-tokenized claims; re-tokenize the same
    // texts in Rust and compare ids — end-to-end tokenizer parity on real
    // claim strings (the fixture test covers adversarial cases).
    let Some(m) = manifest_or_skip() else { return };
    let p = m.profile("tiny").unwrap();
    let tok =
        HashTokenizer::new(p.config.vocab_size as u32, p.config.seq_len);
    let golden = read_json(&m, &p.golden);
    let case = golden.req("cases").unwrap().idx(0).unwrap();
    let texts: Vec<&str> = case
        .req("texts")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|t| t.as_str().unwrap())
        .collect();
    let want: Vec<i64> = case
        .req("tokens")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .flat_map(|row| row.as_array().unwrap().iter())
        .map(|v| v.as_f64().unwrap() as i64)
        .collect();
    let got: Vec<i64> = texts
        .iter()
        .flat_map(|t| tok.encode(t))
        .map(|x| x as i64)
        .collect();
    assert_eq!(got, want);
}

#[test]
fn tokenizer_fixture_parity() {
    let Some(m) = manifest_or_skip() else { return };
    let fixture = read_json(&m, "tokenizer_fixture.json");
    assert_eq!(
        fixture.req("reserved").unwrap().as_u64().unwrap(),
        pcm::runtime::tokenizer::RESERVED as u64
    );
    for entry in fixture.req("entries").unwrap().as_array().unwrap() {
        let tok = HashTokenizer::new(
            entry.req("vocab_size").unwrap().as_u64().unwrap() as u32,
            entry.req("seq_len").unwrap().as_usize().unwrap(),
        );
        for case in entry.req("cases").unwrap().as_array().unwrap() {
            let text = case.req("text").unwrap().as_str().unwrap();
            let want: Vec<u32> = case
                .req("ids")
                .unwrap()
                .as_array()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as u32)
                .collect();
            assert_eq!(tok.encode(text), want, "text={text:?}");
        }
    }
}

#[test]
fn infer_texts_handles_ragged_batch_sizes() {
    let Some(m) = manifest_or_skip() else { return };
    let p = m.profile("tiny").unwrap().clone();
    let ctx = ModelContext::materialize(&m, "tiny", &p.batch_sizes).unwrap();
    // 7 texts over artifacts {1,4}: chunks 4+1+1+1, all rows returned.
    let texts: Vec<String> =
        (0..7).map(|i| format!("claim number {i} is great")).collect();
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let logits = ctx.infer_texts(&refs).unwrap();
    assert_eq!(logits.len(), 7);
    for row in &logits {
        assert_eq!(row.len(), 3);
        assert!(row.iter().all(|v| v.is_finite()));
    }
    // Same text in different chunk positions must yield identical logits.
    let twice = ctx.infer_texts(&[refs[0], refs[0]]).unwrap();
    for (a, b) in twice[0].iter().zip(&twice[1]) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn engine_classifies_deterministically() {
    let Some(m) = manifest_or_skip() else { return };
    let p = m.profile("tiny").unwrap().clone();
    let ctx = ModelContext::materialize(&m, "tiny", &p.batch_sizes).unwrap();
    let engine = InferenceEngine::new(ctx);
    let texts = ["water is wet", "the moon is cheese"];
    let a = engine.classify(&texts).unwrap();
    let b = engine.classify(&texts).unwrap();
    assert_eq!(a, b);
}

#[test]
fn context_init_stats_populated() {
    let Some(m) = manifest_or_skip() else { return };
    let ctx = ModelContext::materialize(&m, "tiny", &[1]).unwrap();
    // Staging/compile take nonzero time; upload may round to ~0 but the
    // total must be positive — this is the cost pervasive context
    // management amortizes.
    assert!(ctx.init_stats.total_s() > 0.0);
    assert!(ctx.init_stats.compile_s > 0.0);
}
