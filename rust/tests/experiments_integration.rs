//! Experiments-harness integration: scaled-down versions of the paper's
//! evaluation, asserting the *shape* of every headline claim (who wins,
//! by roughly what factor, where crossovers fall). The full-scale runs
//! live in `cargo bench` / `pcm experiment` and EXPERIMENTS.md.

use pcm::coordinator::SimDriver;
use pcm::experiments::figures;
use pcm::experiments::runner::ExperimentResult;
use pcm::experiments::specs::{figure4_specs, spec_by_id};

const SEED: u64 = 42;
/// 10% of the paper's 150 k inferences — big enough for stable shapes.
const SCALE: f64 = 0.10;

fn run_scaled(id: &str) -> ExperimentResult {
    let spec = spec_by_id(id).expect(id);
    let mut cfg = spec.build(SEED);
    for app in &mut cfg.apps {
        app.total_inferences =
            ((app.total_inferences as f64 * SCALE) as u64).max(100);
    }
    let outcome = SimDriver::new(cfg).run();
    ExperimentResult {
        id: id.to_string(),
        policy: outcome.summary.policy,
        batch_size: outcome.summary.batch_size,
        exec_time_s: outcome.summary.exec_time_s,
        avg_workers: outcome.summary.avg_workers,
        outcome,
    }
}

#[test]
fn effort1_naive_scaling_is_disappointing() {
    // pv1 on 20 GPUs speeds up pv0 by only ~3.9× (paper) — far below the
    // ideal 15×. Accept the 2–8× band.
    let pv0 = run_scaled("pv0");
    let pv1 = run_scaled("pv1");
    let speedup = pv0.exec_time_s / pv1.exec_time_s;
    assert!(
        (2.0..8.0).contains(&speedup),
        "naive speedup {speedup:.2} (paper: 3.9)"
    );
}

#[test]
fn effort2_partial_context_improves_on_naive() {
    // pv2 ≈ 7.7× vs pv1 ≈ 3.9× (paper): partial context must beat naive.
    let pv1 = run_scaled("pv1");
    let pv2 = run_scaled("pv2");
    assert!(
        pv2.exec_time_s < pv1.exec_time_s * 0.8,
        "pv2 {} !≪ pv1 {}",
        pv2.exec_time_s,
        pv1.exec_time_s
    );
}

#[test]
fn effort3_partial_batch_sweep_is_parabolic() {
    // pv3: both extremes lose to the middle; pv3_1 is catastrophic
    // (paper: 141.1 ks, 3.4× WORSE than the 1-GPU baseline).
    let b1 = run_scaled("pv3_1");
    let b1k = run_scaled("pv3_1k");
    let b75 = run_scaled("pv3_7.5k");
    assert!(b1.exec_time_s > 2.0 * b1k.exec_time_s, "left arm of parabola");
    assert!(
        b75.exec_time_s > 1.2 * b1k.exec_time_s,
        "right arm: {} vs {}",
        b75.exec_time_s,
        b1k.exec_time_s
    );
    let pv0 = run_scaled("pv0");
    assert!(
        b1.exec_time_s > pv0.exec_time_s,
        "pv3_1 must be worse than the dedicated baseline"
    );
}

#[test]
fn effort4_pervasive_flattens_batch_curve_and_shifts_optimum() {
    // pv4: any B in [1, 1k] within ~tens of %; optimum shifts to small B;
    // pv4_1 and pv4_100 beat their pv3 counterparts dramatically.
    let p1 = run_scaled("pv4_1");
    let p100 = run_scaled("pv4_100");
    let q1 = run_scaled("pv3_1");
    let q100 = run_scaled("pv3_100");

    // (B=1000 would mean 15 tasks on 20 workers at 10% scale — a pure
    // straggler artifact — so the flatness check uses B ∈ {1, 100}; the
    // full [1, 1k] spread is asserted at full scale in bench_fig4.)
    let spread = [p1.exec_time_s, p100.exec_time_s];
    let max = spread.iter().cloned().fold(f64::MIN, f64::max);
    let min = spread.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        max / min < 1.6,
        "pervasive batch spread {:.2} (paper: ≤1.12)",
        max / min
    );
    // Paper: pv4_1 97.8% better than pv3_1; pv4_100 44.5% better than pv3_100.
    assert!(p1.exec_time_s < 0.15 * q1.exec_time_s, "pv4_1 vs pv3_1");
    assert!(p100.exec_time_s < 0.9 * q100.exec_time_s, "pv4_100 vs pv3_100");
}

#[test]
fn effort5_drain_pervasive_does_more_work() {
    // Figure 6: pv5s completes meaningfully more inferences than pv5p
    // (paper: +36.7%, 16.9 k gap). Full scale — at 10% the workload
    // finishes before the drain begins and the comparison degenerates.
    let run_full = |id: &str| {
        let spec = spec_by_id(id).expect(id);
        let outcome = SimDriver::new(spec.build(SEED)).run();
        ExperimentResult {
            id: id.to_string(),
            policy: outcome.summary.policy,
            batch_size: outcome.summary.batch_size,
            exec_time_s: outcome.summary.exec_time_s,
            avg_workers: outcome.summary.avg_workers,
            outcome,
        }
    };
    let s = run_full("pv5s");
    let p = run_full("pv5p");
    let cs = s.outcome.summary.completed_inferences;
    let cp = p.outcome.summary.completed_inferences;
    assert!(
        cs > cp,
        "pervasive must complete more under drain: {cs} vs {cp}"
    );
    // And discard less in-flight work per eviction (B=100 vs B=1000).
    assert!(
        s.outcome.summary.evicted_inferences
            < p.outcome.summary.evicted_inferences
    );
    // Throughput dominance at (almost) all times: compare completion
    // curves at each shared sample instant.
    let better_or_equal = s
        .outcome
        .series
        .iter()
        .zip(p.outcome.series.iter())
        .filter(|(a, b)| {
            a.completed_inferences >= b.completed_inferences
        })
        .count();
    let total = s.outcome.series.len().min(p.outcome.series.len());
    assert!(
        better_or_equal as f64 / total as f64 > 0.8,
        "pv5s throughput should dominate most of the run"
    );
}

#[test]
fn effort6_unrestricted_scaling_tracks_availability() {
    // pv6 (quiet day, up to 186 GPUs) must beat every 20-GPU experiment
    // and the busy-night run (pv6_11p) must be the slow one.
    let pv6 = run_scaled("pv6");
    let pv6_11p = run_scaled("pv6_11p");
    let pv4_100 = run_scaled("pv4_100");
    assert!(pv6.exec_time_s < pv4_100.exec_time_s, "186 GPUs beat 20");
    assert!(pv6.avg_workers > 80.0, "avg={}", pv6.avg_workers);
    assert!(
        pv6_11p.exec_time_s > pv6.exec_time_s,
        "busy night slower than quiet day"
    );
    assert!(pv6_11p.avg_workers < 70.0);
}

#[test]
fn headline_98_percent_reduction_shape() {
    // Paper headline: 98.1% reduction (40.9 ks → 783 s = 52×). At 10%
    // scale ramp-up overheads weigh more; accept ≥90% reduction (≥10×).
    let pv0 = run_scaled("pv0");
    let pv6 = run_scaled("pv6");
    let reduction = 1.0 - pv6.exec_time_s / pv0.exec_time_s;
    assert!(
        reduction > 0.90,
        "reduction {:.3} (paper: 0.981)",
        reduction
    );
}

#[test]
fn figure_renderers_produce_wellformed_output() {
    let results = vec![run_scaled("pv0"), run_scaled("pv4_100")];
    let t = figures::figure4_text(&results);
    assert!(t.contains("pv0") && t.contains("pv4_100"));
    let csv = figures::figure4_csv(&results);
    assert_eq!(csv.lines().count(), 3); // header + 2 rows
    let t2 = figures::table2(&results);
    assert!(t2.contains("Mean"));
    let ts = figures::timeseries_csv(&results);
    assert!(ts.lines().count() > 10);
    let f5 = figures::figure5_csv(&results);
    assert!(f5.lines().count() > 100); // one row per task record
}

#[test]
fn spec_list_is_complete_and_buildable() {
    let specs = figure4_specs();
    assert_eq!(specs.len(), 21);
    for s in &specs {
        let cfg = s.build(7);
        assert!(!cfg.nodes.is_empty());
        assert!(cfg.batch_size >= 1);
    }
}
