//! Golden decision-parity tests for the *indexed* scheduler state.
//!
//! The indexed-dispatch refactor's contract is that every shipped
//! policy makes bit-for-bit identical placement decisions on top of the
//! incremental indexes (warm-worker sets, per-context counters, order
//! keys, memoized estimates) as it did over full scans. Each
//! `reference_*` below is a verbatim port of the pre-index algorithm,
//! recomputing warmth and idleness by scanning public worker state and
//! walking the whole ready queue; the tests replay them side by side
//! with the shipped policies across randomized multi-tenant churn
//! storms (joins, evictions, reclaim forecasts, phase progress),
//! asserting identical `Vec<PlacementDecision>` every dispatch round.
//! `Scheduler::check_index_consistency` — itself a from-scratch
//! recomputation of every index — is asserted after every event, which
//! extends the parity to the accessor values the references share with
//! the live policies (memoized acquisition estimates, prefetch
//! counters).

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

use pcm::cluster::{GpuModel, Node};
use pcm::coordinator::policy::{
    pick_best_worker_filtered, AffinityGreedy, PlacementDecision,
    PlacementPolicy, QueuedTask, RiskAware, SchedulerView, WarmPrefetch,
    WeightedFairShare,
};
use pcm::coordinator::{
    ContextId, ContextPolicy, ContextRecipe, CostModel, Scheduler, Task,
    TaskId, TaskRecord, TransferPlanner, WorkerId,
};
use pcm::util::Rng;

/// The warm-pairing look-ahead depth shared by greedy and riskaware.
const LOOKAHEAD: usize = 64;

// ------------------------------------------------ scan-based accessors
//
// The references must not trust the indexes they are refereeing, so
// idleness and warmth are recomputed from public worker state on every
// call — exactly what the pre-index `SchedulerView` did.

fn idle_scan(sched: &Scheduler) -> Vec<WorkerId> {
    let mut idle: Vec<WorkerId> = sched
        .workers()
        .filter(|w| w.is_idle())
        .map(|w| w.id)
        .collect();
    idle.sort_unstable();
    idle
}

/// Pre-index `SchedulerView::warm_for`: fully warm under the current
/// policy (ready library under Pervasive; every cached-up-front
/// component present under file-caching policies).
fn warm_for_scan(sched: &Scheduler, wid: WorkerId, ctx: ContextId) -> bool {
    let Some(w) = sched.worker(wid) else { return false };
    let policy = sched.policy();
    if policy.retains_materialized() {
        w.library.is_ready_for(ctx)
    } else if policy.caches_files() {
        sched
            .recipe(ctx)
            .expect("storm contexts are registered")
            .cached_components(policy)
            .iter()
            .all(|c| w.has_cached(ctx, c.kind))
    } else {
        false
    }
}

/// Pre-index `SchedulerView::cache_warm_for`: ready library (any
/// policy) or a complete, non-empty file cache.
fn cache_warm_for_scan(sched: &Scheduler, wid: WorkerId, ctx: ContextId) -> bool {
    let Some(w) = sched.worker(wid) else { return false };
    if w.library.is_ready_for(ctx) {
        return true;
    }
    let policy = sched.policy();
    if !policy.caches_files() {
        return false;
    }
    let Some(recipe) = sched.recipe(ctx) else { return false };
    let comps = recipe.cached_components(policy);
    !comps.is_empty() && comps.iter().all(|c| w.has_cached(ctx, c.kind))
}

/// Pre-index `SchedulerView::warm_worker_count`: a full pool scan.
fn warm_worker_count_scan(sched: &Scheduler, ctx: ContextId) -> usize {
    sched
        .workers()
        .filter(|w| cache_warm_for_scan(sched, w.id, ctx))
        .count()
}

// ------------------------------------------------- reference policies

/// Verbatim pre-index `AffinityGreedy::place` (whole queue walked, warm
/// pairing by per-worker component scan).
fn reference_greedy(
    sched: &Scheduler,
    view: &SchedulerView,
) -> Vec<PlacementDecision> {
    let mut decisions = Vec::new();
    let mut idle = idle_scan(sched);
    if idle.is_empty() {
        return decisions;
    }
    let mut queue = view.queued_prefix(usize::MAX);
    if queue.is_empty() {
        return decisions;
    }
    let mut i = 0;
    while i < idle.len() {
        let wid = idle[i];
        let mut found = None;
        for (pos, q) in queue.iter().enumerate().take(LOOKAHEAD) {
            if warm_for_scan(sched, wid, q.context) {
                found = Some(pos);
                break;
            }
        }
        if let Some(pos) = found {
            let q = queue.remove(pos);
            let wid = idle.remove(i);
            decisions
                .push(PlacementDecision::Assign { task: q.task, worker: wid });
        } else {
            i += 1;
        }
    }
    for q in queue {
        if idle.is_empty() {
            break;
        }
        let best = pick_best_worker_filtered(view, &idle, q.context, |_| true)
            .expect("idle is non-empty");
        let wid = idle.swap_remove(best);
        decisions.push(PlacementDecision::Assign { task: q.task, worker: wid });
    }
    decisions
}

/// Verbatim pre-index `WeightedFairShare::place`: whole-queue DRR over
/// `VecDeque`s, deficits threaded by the caller across rounds.
fn reference_fairshare(
    sched: &Scheduler,
    view: &SchedulerView,
    deficits: &mut BTreeMap<ContextId, f64>,
) -> Vec<PlacementDecision> {
    let mut decisions = Vec::new();
    let queued = view.queued_prefix(usize::MAX);
    if queued.is_empty() {
        deficits.clear();
        return decisions;
    }
    let mut idle = idle_scan(sched);

    let mut queues: BTreeMap<ContextId, VecDeque<QueuedTask>> = BTreeMap::new();
    for q in queued {
        queues.entry(q.context).or_default().push_back(q);
    }
    deficits.retain(|ctx, _| queues.contains_key(ctx));

    let quantum = queues
        .values()
        .flat_map(|q| q.iter().map(|t| t.inferences))
        .max()
        .unwrap_or(1) as f64;

    while !idle.is_empty() && queues.values().any(|q| !q.is_empty()) {
        let mut progressed = false;
        for (ctx, q) in queues.iter_mut() {
            if q.is_empty() || idle.is_empty() {
                continue;
            }
            let d = deficits.entry(*ctx).or_insert(0.0);
            let w = view.recipe_weight(*ctx);
            if w.is_finite() && w > 0.0 {
                *d += quantum * w;
            }
            while let Some(head) = q.front().copied() {
                if idle.is_empty() || *d + 1e-9 < head.inferences as f64 {
                    break;
                }
                let best =
                    pick_best_worker_filtered(view, &idle, *ctx, |_| true)
                        .expect("idle is non-empty");
                let wid = idle.swap_remove(best);
                *d -= head.inferences as f64;
                q.pop_front();
                decisions.push(PlacementDecision::Assign {
                    task: head.task,
                    worker: wid,
                });
                progressed = true;
            }
            if let Some(max_left) = q.iter().map(|t| t.inferences).max() {
                *d = d.min(max_left as f64);
            }
        }
        if !progressed {
            if idle.is_empty() {
                break;
            }
            for (ctx, q) in queues.iter() {
                if let Some(head) = q.front() {
                    let d = deficits.entry(*ctx).or_insert(0.0);
                    *d = d.max(head.inferences as f64);
                }
            }
        }
    }

    deficits.retain(|ctx, d| match queues.get(ctx) {
        Some(q) if !q.is_empty() => {
            let max_left = q.iter().map(|t| t.inferences).max().unwrap_or(1);
            *d = d.min(max_left as f64);
            true
        }
        _ => false,
    });
    decisions
}

/// Verbatim pre-index `WarmPrefetch::place`: whole-queue warm claim
/// scan, unclaimed-rank walk, pool-scan warm counts.
fn reference_prefetch(
    sched: &Scheduler,
    view: &SchedulerView,
    width: usize,
) -> Vec<PlacementDecision> {
    let mut decisions = Vec::new();
    let queue = view.queued_prefix(usize::MAX);
    if queue.is_empty() {
        return decisions;
    }
    let mut idle = idle_scan(sched);
    if idle.is_empty() {
        return decisions;
    }
    let caches = view.context_policy().caches_files();

    let contexts = view.contexts();
    let warm_of: HashMap<WorkerId, HashSet<ContextId>> = idle
        .iter()
        .map(|w| {
            let set = contexts
                .iter()
                .copied()
                .filter(|c| cache_warm_for_scan(sched, *w, *c))
                .collect();
            (*w, set)
        })
        .collect();
    let mut claimed = vec![false; queue.len()];
    let mut i = 0;
    while i < idle.len() {
        let wid = idle[i];
        let warm = &warm_of[&wid];
        let mut found = None;
        for (pos, q) in queue.iter().enumerate() {
            if !claimed[pos] && warm.contains(&q.context) {
                found = Some(pos);
                break;
            }
        }
        if let Some(pos) = found {
            claimed[pos] = true;
            let wid = idle.remove(i);
            decisions
                .push(PlacementDecision::Assign { task: queue[pos].task, worker: wid });
        } else {
            i += 1;
        }
    }

    if caches {
        let mut first_rank: BTreeMap<ContextId, usize> = BTreeMap::new();
        let mut rank = 0usize;
        for (pos, q) in queue.iter().enumerate() {
            if claimed[pos] {
                continue;
            }
            first_rank.entry(q.context).or_insert(rank);
            rank += 1;
        }
        for (ctx, first) in first_rank {
            if idle.is_empty() {
                break;
            }
            if first < idle.len() {
                continue;
            }
            let mut warmish =
                warm_worker_count_scan(sched, ctx) + view.prefetching_count(ctx);
            while warmish < width && !idle.is_empty() {
                let need = view.recipe_cached_bytes(ctx);
                let target = idle
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| view.worker_cache_capacity(**w) >= need)
                    .min_by(|(_, a), (_, b)| {
                        view.worker_cached_bytes(**a)
                            .cmp(&view.worker_cached_bytes(**b))
                            .then(a.cmp(b))
                    })
                    .map(|(i, _)| i);
                let Some(t) = target else { break };
                let wid = idle.remove(t);
                decisions.push(PlacementDecision::Prefetch { ctx, worker: wid });
                warmish += 1;
            }
        }
    }

    for (pos, q) in queue.iter().enumerate() {
        if claimed[pos] {
            continue;
        }
        if idle.is_empty() {
            break;
        }
        let best = pick_best_worker_filtered(view, &idle, q.context, |_| true)
            .expect("idle is non-empty");
        let wid = idle.swap_remove(best);
        decisions.push(PlacementDecision::Assign { task: q.task, worker: wid });
    }
    decisions
}

/// Verbatim pre-index `RiskAware::place`: survival-gated warm pairing by
/// component scan, safe-filtered FIFO, longest-lived backstop.
fn reference_riskaware(
    sched: &Scheduler,
    view: &SchedulerView,
    margin: f64,
) -> Vec<PlacementDecision> {
    let survives = |w: WorkerId, ctx: ContextId, inferences: u64| -> bool {
        let life = view.expected_lifetime_s(w);
        if life.is_infinite() {
            return true;
        }
        let need =
            view.acquisition_estimate_s(w, ctx) + view.est_execute_s(w, inferences);
        need * margin <= life
    };

    let mut decisions = Vec::new();
    let mut idle = idle_scan(sched);
    if idle.is_empty() {
        return decisions;
    }
    let mut queue = view.queued_prefix(usize::MAX);
    if queue.is_empty() {
        return decisions;
    }

    let mut i = 0;
    while i < idle.len() {
        let wid = idle[i];
        let mut found = None;
        for (pos, q) in queue.iter().enumerate().take(LOOKAHEAD) {
            if warm_for_scan(sched, wid, q.context)
                && survives(wid, q.context, q.inferences)
            {
                found = Some(pos);
                break;
            }
        }
        if let Some(pos) = found {
            let q = queue.remove(pos);
            let wid = idle.remove(i);
            decisions
                .push(PlacementDecision::Assign { task: q.task, worker: wid });
        } else {
            i += 1;
        }
    }

    let in_flight = view.in_flight_total();
    let mut held_back = None;
    for q in queue {
        if idle.is_empty() {
            break;
        }
        let best_safe = pick_best_worker_filtered(view, &idle, q.context, |w| {
            survives(w, q.context, q.inferences)
        });
        match best_safe {
            Some(i) => {
                let wid = idle.swap_remove(i);
                decisions
                    .push(PlacementDecision::Assign { task: q.task, worker: wid });
            }
            None => {
                if held_back.is_none() {
                    held_back = Some(q);
                }
            }
        }
    }
    if decisions.is_empty() && in_flight == 0 {
        if let Some(q) = held_back {
            if !idle.is_empty() {
                let mut best = 0usize;
                for i in 1..idle.len() {
                    let (a, b) = (idle[best], idle[i]);
                    let (la, lb) =
                        (view.expected_lifetime_s(a), view.expected_lifetime_s(b));
                    let better = match lb.partial_cmp(&la).unwrap() {
                        std::cmp::Ordering::Greater => true,
                        std::cmp::Ordering::Less => false,
                        std::cmp::Ordering::Equal => match view
                            .worker_speed(b)
                            .partial_cmp(&view.worker_speed(a))
                            .unwrap()
                        {
                            std::cmp::Ordering::Greater => true,
                            std::cmp::Ordering::Less => false,
                            std::cmp::Ordering::Equal => b < a,
                        },
                    };
                    if better {
                        best = i;
                    }
                }
                let wid = idle.swap_remove(best);
                decisions
                    .push(PlacementDecision::Assign { task: q.task, worker: wid });
            }
        }
    }
    decisions
}

// ------------------------------------------------------- storm harness

fn task_record(task: TaskId, worker: WorkerId, n: u64, ctx: u32) -> TaskRecord {
    TaskRecord {
        task,
        context: ctx,
        worker,
        gpu: GpuModel::A10,
        attempts: 1,
        inferences: n,
        dispatched_at: 0.0,
        completed_at: 1.0,
        context_s: 0.0,
        execute_s: 1.0,
    }
}

/// Drive one randomized churn storm: joins, evictions, optional
/// reclaim-forecast updates, phase progress, and parity-checked
/// dispatch rounds executed through `apply_decisions` (so prefetches
/// run too). Every event re-validates conservation, cache capacity, and
/// full index consistency against from-scratch recomputation.
fn run_storm(
    seed: u64,
    salt: u64,
    reclaim_hints: bool,
    live: &mut dyn PlacementPolicy,
    reference: &mut dyn FnMut(&Scheduler, &SchedulerView) -> Vec<PlacementDecision>,
) {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9) ^ salt);
    let policy = match rng.below(3) {
        0 => ContextPolicy::None,
        1 => ContextPolicy::Partial,
        _ => ContextPolicy::Pervasive,
    };
    let capacity = (8 + rng.below(17) as u64) * 1_000_000_000;
    let mut big =
        ContextRecipe::custom(1, "big", 5_000_000_000, 10_000_000_000);
    // Unequal tenant weights so fair-share storms exercise real DRR
    // credit ratios (ignored by the other policies).
    big.weight = (1 + rng.below(4)) as f64 * 0.5;
    let mut sched = Scheduler::with_registry(
        policy,
        vec![ContextRecipe::smollm2_pff(0), big],
        TransferPlanner::new(1 + rng.below(4) as u32),
        CostModel::default(),
        capacity,
    );
    let n_tasks = 5 + rng.below(40) as u64;
    let tasks: Vec<Task> = (0..n_tasks)
        .map(|i| Task::new(i, i * 10, 1 + rng.below(100) as u64, rng.below(2) as u32))
        .collect();
    sched.submit_tasks(tasks);

    let gpus =
        [GpuModel::A10, GpuModel::TitanXPascal, GpuModel::H100, GpuModel::A40];
    let mut next_node = 0u32;
    // Running tasks AND in-flight prefetches: (id, worker, phases, next).
    let mut running: Vec<(u64, u32, usize, usize)> = Vec::new();
    let mut guard = 0;
    while !sched.all_done() {
        guard += 1;
        assert!(guard < 100_000, "storm did not converge (seed {seed})");
        sched.set_clock_hint(guard as f64);
        match rng.below(10) {
            0 | 1 => {
                let node =
                    Node { id: next_node, gpu: gpus[rng.below(gpus.len())] };
                next_node += 1;
                sched.worker_join(node, guard as f64);
            }
            2 => {
                let ids: Vec<u32> = sched.workers().map(|w| w.id).collect();
                if !ids.is_empty() {
                    let victim = ids[rng.below(ids.len())];
                    sched.worker_evict(victim);
                    running.retain(|(_, w, _, _)| *w != victim);
                }
            }
            3 if reclaim_hints && next_node > 0 => {
                // Forecast churn: (re)set or clear a node's expected
                // reclamation, sometimes already in the past.
                let node = rng.below(next_node as usize) as u32;
                let hint = if rng.chance(0.3) {
                    None
                } else {
                    Some(guard as f64 + rng.below(2_000) as f64 - 100.0)
                };
                sched.set_node_reclaim_hint(node, hint);
            }
            _ => {
                if running.is_empty() || rng.chance(0.25) {
                    // THE PARITY CHECK: scan-based reference vs indexed
                    // policy on the same frozen state, then execute.
                    let expect =
                        reference(&sched, &SchedulerView::new(&sched));
                    let got = live.place(&SchedulerView::new(&sched));
                    assert_eq!(
                        got, expect,
                        "decision divergence (seed {seed}, round {guard})"
                    );
                    for d in sched.apply_decisions(got) {
                        running.push((d.task, d.worker, d.phases.len(), 0));
                    }
                } else {
                    let i = rng.below(running.len());
                    let (id, worker, n_phases, next) = &mut running[i];
                    sched.phase_done(*id, *next);
                    *next += 1;
                    if *next == *n_phases {
                        if !Scheduler::is_prefetch_id(*id) {
                            let (_, inferences) = sched.task_meta(*id).unwrap();
                            let ctx = sched.task_context(*id).unwrap();
                            sched.task_done(
                                *id,
                                task_record(*id, *worker, inferences, ctx),
                            );
                        }
                        running.remove(i);
                    }
                }
            }
        }
        assert!(sched.check_conservation());
        assert!(sched.check_cache_capacity());
        assert!(
            sched.check_index_consistency(),
            "index divergence (seed {seed}, round {guard})"
        );
    }
}

#[test]
fn indexed_greedy_matches_scan_reference() {
    for seed in 0..16u64 {
        let mut live = AffinityGreedy::new();
        run_storm(seed, 0x16a1, false, &mut live, &mut |s, v| {
            reference_greedy(s, v)
        });
    }
}

#[test]
fn indexed_fairshare_matches_scan_reference() {
    for seed in 0..16u64 {
        let mut live = WeightedFairShare::new();
        // Reference deficits evolve independently across the whole
        // storm — stateful parity, not just per-round.
        let mut deficits: BTreeMap<ContextId, f64> = BTreeMap::new();
        run_storm(seed, 0xfa12, false, &mut live, &mut |s, v| {
            reference_fairshare(s, v, &mut deficits)
        });
    }
}

#[test]
fn indexed_prefetch_matches_scan_reference() {
    for seed in 0..16u64 {
        let mut live = WarmPrefetch::default();
        let width = live.width;
        run_storm(seed, 0x9f3c, false, &mut live, &mut |s, v| {
            reference_prefetch(s, v, width)
        });
    }
}

#[test]
fn indexed_riskaware_matches_scan_reference() {
    for seed in 0..16u64 {
        let mut live = RiskAware::new();
        let margin = live.margin;
        run_storm(seed, 0x415c, true, &mut live, &mut |s, v| {
            reference_riskaware(s, v, margin)
        });
    }
}
