//! Offline stub of the `xla` PJRT bindings.
//!
//! The build environment has no XLA/PJRT shared libraries, so this crate
//! provides the exact API surface `pcm::runtime::engine` compiles against
//! and fails at **client creation** with a descriptive error. Every code
//! path that needs real inference (live mode, golden-logit tests, PJRT
//! benches) already gates on the presence of `artifacts/manifest.json`
//! and skips cleanly when artifacts are absent, so the stub never
//! executes in the test suite — it only has to type-check.
//!
//! To enable real inference, replace the `xla = { path = "xla" }`
//! dependency in `rust/Cargo.toml` with the real bindings (the
//! `xla_extension`-backed crate this API mirrors); no `pcm` source
//! changes are required.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: carries the reason the PJRT backend is unavailable.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT backend unavailable: pcm was built against the offline xla \
         stub (rust/xla). Swap in the real xla bindings to run live \
         inference."
            .to_string(),
    ))
}

/// PJRT client handle (stub: creation always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Host-side literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub: text parsing always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, Error> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"));
    }
}
